"""§Perf: the tiered serving subsystem.

Three measurements:

  1. prefill speedup — ``ServeEngine.generate`` (one-shot prefill +
     continuous-batching decode) vs the seed token-by-token prompt path
     (``generate_sequential``) on a 128-token prompt.  Acceptance bar:
     >= 5x.
  2. continuous-batching scheduler — Poisson arrivals through
     ``ContinuousBatchingScheduler``: TTFT/TPOT percentiles, throughput,
     slot reuse.
  3. calibration bridge — ``ReplicaPool.measure()`` per tier ->
     ``LatencyModel.from_measurements`` -> the routing simulator in
     calibrated mode, next to the constant closed-form model.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.topology import ClusterTopology
from repro.models import make_model
from repro.routing import LatencyModel, SimConfig, simulate
from repro.serving import (ContinuousBatchingScheduler, ReplicaPool,
                           ServeEngine, lm_tiers, poisson_requests,
                           requests_from_events)


def bench_prefill_speedup(arch: str, prompt_len: int = 128,
                          steps: int = 8, batch: int = 2,
                          repeats: int = 3) -> dict:
    cfg = get_config(arch).reduced()
    api = make_model(cfg)
    params, _ = api.init_params(jax.random.key(0))
    eng = ServeEngine(cfg, params, batch_size=max(batch, 2),
                      max_len=2 * prompt_len)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, max(cfg.model.vocab_size, 2), (batch, prompt_len)),
        jnp.int32)
    # warmup both paths (compile)
    out_new = eng.generate(prompt, steps=steps)
    out_seq = eng.generate_sequential(prompt, steps=steps)
    match = bool(np.array_equal(np.asarray(out_new), np.asarray(out_seq)))

    def timed(fn):
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            np.asarray(fn(prompt, steps=steps))
            best = min(best, time.perf_counter() - t0)
        return best * 1e3

    t_new = timed(eng.generate)
    t_seq = timed(eng.generate_sequential)
    speedup = t_seq / t_new
    emit(f"serving_generate_{arch}", t_new * 1e3,
         f"seed_path_ms={t_seq:.1f};prefill_path_ms={t_new:.1f};"
         f"speedup={speedup:.1f}x;greedy_match={match}")
    return {"arch": arch, "ms_new": t_new, "ms_seq": t_seq,
            "speedup": speedup, "greedy_match": match}


def bench_scheduler(arch: str, slots: int = 4, rate: float = 20.0,
                    duration_s: float = 1.0, prompt_len: int = 24,
                    steps: int = 8) -> dict:
    cfg = get_config(arch).reduced()
    api = make_model(cfg)
    params, _ = api.init_params(jax.random.key(0))
    eng = ServeEngine(cfg, params, batch_size=slots, max_len=256)
    eng.measure(prompt_len=prompt_len, decode_steps=2)    # warm compiles
    rng = np.random.default_rng(0)
    events = poisson_requests(np.full(slots, rate / slots), duration_s,
                              seed=0)
    prompts = rng.integers(0, max(cfg.model.vocab_size, 2),
                           (len(events), prompt_len))
    reqs = requests_from_events(events, prompts, max_new_tokens=steps)
    stats = ContinuousBatchingScheduler(eng).run(reqs)
    emit(f"serving_scheduler_{arch}",
         float(np.median(stats.ttft_ms)) * 1e3 if stats.ttft_ms.size else 0,
         f"requests={len(reqs)};{stats.summary().replace(' | ', ';')}")
    return {"requests": len(reqs),
            "ttft_p50_ms": float(np.median(stats.ttft_ms)),
            "tpot_mean_ms": float(stats.tpot_ms.mean())
            if stats.tpot_ms.size else 0.0,
            "tokens_per_s": stats.tokens_per_s,
            "slot_reuses": stats.slot_reuses}


def bench_calibrated_sim(arch: str = "", duration_s: float = 30.0) -> dict:
    """ReplicaPool -> LatencyModel.from_measurements -> simulator."""
    pool = ReplicaPool(lm_tiers(arch)) if arch else ReplicaPool()
    meas = pool.measure(prompt_len=16, decode_steps=4)
    decode_tokens = 0 if not arch else 4
    lat = LatencyModel.from_measurements(meas, decode_tokens=decode_tokens)
    topo = ClusterTopology(assign=np.arange(12) % 3, n_devices=12,
                           n_edges=3, lam=np.full(12, 2.0),
                           r=np.full(3, 10.0), l=2)
    calib = simulate(topo, SimConfig(duration_s=duration_s, seed=1,
                                     latency=lat))
    const = simulate(topo, SimConfig(duration_s=duration_s, seed=1))
    tiers = {t: round(lat.infer_ms(t), 3) for t in pool.tiers}
    emit("serving_calibrated_sim", calib.mean_latency() * 1e3,
         f"calibrated_mean_ms={calib.mean_latency():.2f};"
         f"constant_mean_ms={const.mean_latency():.2f};"
         f"tier_service_ms={tiers}")
    return {"calibrated_mean_ms": calib.mean_latency(),
            "constant_mean_ms": const.mean_latency(),
            "tier_service_ms": tiers}


def report(arch="stablelm-1.6b", out=""):
    print(f"=== tiered serving subsystem ({arch}) ===")
    res = {"prefill": bench_prefill_speedup(arch),
           "scheduler": bench_scheduler(arch),
           "calibrated_sim": bench_calibrated_sim()}
    p = res["prefill"]
    print(f"prefill+decode vs token-by-token: {p['speedup']:.1f}x "
          f"({p['ms_seq']:.0f}ms -> {p['ms_new']:.0f}ms), greedy outputs "
          f"{'match' if p['greedy_match'] else 'DIVERGE'}")
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(res, f, indent=2)
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--out", default="results/perf_serving_scheduler.json")
    a = ap.parse_args()
    report(a.arch, a.out)
