"""Reactive orchestration loop — closes the monitor -> controller ->
re-deploy cycle the paper describes (§III last paragraph) inside the
co-simulation.

Monitors emit telemetry on the shared event core and drive the
``LearningController`` hooks mid-simulation:

  accuracy monitor   modeled validation MSE (drift onset ramps it up,
                     each completed retraining round closes part of the
                     gap) -> ``on_accuracy_alarm`` -> retraining burst
  latency monitor    windowed p95 over the request log; sustained
                     violations pick the bottleneck edge and call
                     ``on_capacity_change`` with its training-degraded
                     effective rate -> HFLOP re-clusters -> the co-sim
                     swaps the deployment (with migration cost)
  failure monitor    ``NODE_FAILURE`` events -> ``on_node_failure`` ->
                     re-cluster around the dead edge

All reactions are deterministic functions of the event stream, so a
reactive run is reproducible seed-for-seed like any other.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.fl.hierarchy import round_schedule
from repro.sim.events import Event, EventKind, Simulation


@dataclass
class AccuracyModel:
    """Closed-form serving-accuracy telemetry: base MSE until drift
    onset, then a ramp toward ``drift_mse`` over ``ramp_s`` seconds;
    every completed retraining round multiplies the remaining gap by
    ``1 - recovery_per_round`` (continual learning re-fits the model)."""
    base_mse: float = 0.03
    drift_mse: float = 0.12
    ramp_s: float = 30.0
    recovery_per_round: float = 0.5
    drift_t: Optional[float] = None
    gap_scale: float = 1.0

    def on_drift(self, t: float, drift_mse: Optional[float] = None) -> None:
        self.drift_t = t
        self.gap_scale = 1.0
        if drift_mse is not None:
            self.drift_mse = float(drift_mse)

    def on_round_complete(self) -> None:
        if self.drift_t is not None:
            self.gap_scale *= (1.0 - self.recovery_per_round)

    def mse(self, t: float) -> float:
        if self.drift_t is None or t < self.drift_t:
            return self.base_mse
        ramp = min((t - self.drift_t) / max(self.ramp_s, 1e-9), 1.0)
        return self.base_mse + self.gap_scale * ramp * (self.drift_mse
                                                        - self.base_mse)


@dataclass
class ReactivePolicy:
    p95_threshold_ms: float = 40.0   # sustained p95 above this -> recluster
    window_s: float = 10.0           # telemetry window for p95
    min_window_requests: int = 20
    cooldown_s: float = 30.0         # between reclusterings
    capacity_derate: float = 0.6     # edge_agg_share estimate used when
    #                                  reporting effective capacity
    feasibility_slack: float = 1.05  # keep sum(r) >= slack * sum(lam)
    burst_rounds: int = 4            # retraining burst on accuracy alarm
    burst_local_epochs: int = 5
    burst_epoch_s: float = 4.0
    burst_upload_s: float = 1.5
    restore_idle_s: float = 20.0     # training idle this long -> restore
    #                                  nominal capacities (and re-cluster)


class ReactiveLoop:
    """Binds a ``LearningController`` to a running :class:`CoSim`."""

    def __init__(self, controller, accuracy: Optional[AccuracyModel] = None,
                 policy: Optional[ReactivePolicy] = None):
        self.controller = controller
        self.acc = accuracy if accuracy is not None else AccuracyModel()
        self.policy = policy if policy is not None else ReactivePolicy()
        self.mse_series: List[Tuple[float, float]] = []
        self.actions: List[Tuple[float, str]] = []
        self.burst_until = -math.inf
        self.last_recluster_t = -math.inf
        # nominal (pre-derate) capacity per edge id: derates are computed
        # from here so repeated alarms don't compound, and capacities are
        # restored once training goes idle
        self._nominal_caps: dict = {}
        self.cosim = None

    def bind(self, cosim) -> None:
        self.cosim = cosim
        sim: Simulation = cosim.sim
        sim.on(EventKind.TELEMETRY, self.on_telemetry)
        sim.on(EventKind.DRIFT_ONSET, self.on_drift)
        sim.on(EventKind.NODE_FAILURE, self.on_node_failure)
        sim.on(EventKind.CAPACITY_CHANGE, self.on_capacity_change)
        sim.on(EventKind.ROUND_END, self.on_round_end)
        tick = cosim.cfg.telemetry_s
        n_ticks = int(cosim.cfg.duration_s / tick)
        for k in range(1, n_ticks + 1):
            sim.schedule(k * tick, EventKind.TELEMETRY)

    # -- environment events -> controller hooks -----------------------------

    def on_drift(self, sim: Simulation, ev: Event) -> None:
        self.acc.on_drift(ev.t, drift_mse=ev.payload)
        self.actions.append((ev.t, "drift onset"))

    def on_round_end(self, sim: Simulation, ev: Event) -> None:
        self.acc.on_round_complete()

    def on_node_failure(self, sim: Simulation, ev: Event) -> None:
        failed = int(ev.node)
        # edge ids above the removed one shift down, like lan_edge refs
        self._nominal_caps = {(j - 1 if j > failed else j): cap
                              for j, cap in self._nominal_caps.items()
                              if j != failed}
        dep = self.controller.on_node_failure(int(ev.node))
        self.cosim.apply_deployment(dep)
        self.actions.append((ev.t, f"edge {ev.node} failed -> reclustered "
                             f"to {len(dep.topology.open_edges)} edges"))

    def on_capacity_change(self, sim: Simulation, ev: Event) -> None:
        # a real hardware capacity change supersedes any derated nominal
        # we recorded — _restore_capacity must not revert it later
        self._nominal_caps.pop(int(ev.node), None)
        dep = self.controller.on_capacity_change(int(ev.node),
                                                 float(ev.payload))
        self.cosim.apply_deployment(dep)
        self.actions.append((ev.t, f"edge {ev.node} capacity -> "
                             f"{float(ev.payload):.2f} rps, reclustered"))

    # -- telemetry tick ------------------------------------------------------

    def on_telemetry(self, sim: Simulation, ev: Event) -> None:
        t = ev.t
        mse = self.acc.mse(t)
        self.mse_series.append((t, mse))
        if (self.controller.on_accuracy_alarm(mse)
                and t >= self.burst_until):
            self._trigger_retraining(t, mse)
        p95 = self._window_p95(t)
        if (p95 is not None and p95 > self.policy.p95_threshold_ms
                and t - self.last_recluster_t >= self.policy.cooldown_s):
            self._recluster_for_latency(t, p95)
        elif (self._nominal_caps and not self.cosim.training_active
                and t - self.cosim.last_round_end
                >= self.policy.restore_idle_s
                and t - self.last_recluster_t >= self.policy.cooldown_s):
            self._restore_capacity(t)

    def _trigger_retraining(self, t: float, mse: float) -> None:
        p = self.policy
        burst = round_schedule(p.burst_rounds, l=self.controller.l,
                               local_epochs=p.burst_local_epochs,
                               epoch_s=p.burst_epoch_s,
                               upload_s=p.burst_upload_s, start_s=t)
        self.cosim.add_training(burst)
        self.burst_until = burst[-1].end
        self.actions.append((t, f"accuracy alarm (mse={mse:.3f}) -> "
                             f"retraining burst of {p.burst_rounds} rounds"))

    def _window_p95(self, t: float) -> Optional[float]:
        return self.cosim.proc.recent_percentile(
            t, self.policy.window_s, 95,
            min_requests=self.policy.min_window_requests)

    def _recluster_for_latency(self, t: float, p95: float) -> None:
        """Pick the busiest edge in the window and report its effective
        (training-degraded) capacity to the controller, which re-solves
        HFLOP — load moves off the bottleneck."""
        proc = self.cosim.proc
        edges = proc.edges
        if not edges:
            return
        # bottleneck = edge with the highest assigned request load
        loads = self.cosim.proc.topo.cluster_loads()
        if not loads:
            return
        bottleneck = max(loads, key=loads.get)
        inv_edges = self.controller.inventory.edges
        if bottleneck >= len(inv_edges):
            return
        cur = inv_edges[bottleneck].capacity_rps
        # derate from the NOMINAL capacity, not the current value —
        # repeated alarms must not compound toward zero
        nominal = self._nominal_caps.get(bottleneck, cur)
        eff = nominal * (1.0 - self.policy.capacity_derate)
        # never report a capacity that makes the instance infeasible
        lam_total = sum(d.lam for d in self.controller.inventory.devices)
        others = sum(e.capacity_rps for e in inv_edges) - cur
        eff = max(eff, self.policy.feasibility_slack * lam_total - others)
        if eff >= cur * 0.999:
            return                   # no meaningful reduction possible
        self._nominal_caps.setdefault(bottleneck, nominal)
        dep = self.controller.on_capacity_change(bottleneck, float(eff))
        self.cosim.apply_deployment(dep)
        self.last_recluster_t = t
        self.actions.append(
            (t, f"latency alarm (p95={p95:.1f}ms) -> edge {bottleneck} "
             f"effective capacity {eff:.2f} rps, reclustered"))

    def _restore_capacity(self, t: float) -> None:
        """Training has been idle long enough: the interference the
        derated capacities modeled is gone, so hand the controller its
        nominal rates back and re-cluster once."""
        inv_edges = self.controller.inventory.edges
        items = [(j, cap) for j, cap in sorted(self._nominal_caps.items())
                 if j < len(inv_edges)]
        self._nominal_caps.clear()
        if not items:
            return
        for j, cap in items[:-1]:
            inv_edges[j].capacity_rps = cap
        last_j, last_cap = items[-1]
        dep = self.controller.on_capacity_change(last_j, float(last_cap))
        self.cosim.apply_deployment(dep)
        self.last_recluster_t = t
        self.actions.append((t, "training idle -> nominal edge capacities "
                             "restored, reclustered"))
