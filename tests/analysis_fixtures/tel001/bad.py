"""TEL001 bad fixture: telemetry-guarded block perturbing the sim."""


class Handler:
    def __init__(self, sim, tel, rng):
        self.sim = sim
        self._tel = tel
        self.rng = rng
        self.pending = []

    def on_event(self, ev):
        if self._tel is not None:
            jitter = self.rng.normal()          # RNG drawn only when on
            self.sim.schedule(ev.t + jitter)    # extra event only when on
            self.sim.busy = True                # observable mutation
            self.pending.append(ev)             # observable mutation
            self._tel.metrics.counter("events").inc()
