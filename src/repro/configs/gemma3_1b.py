"""gemma3-1b [dense] — 5:1 local:global attention, 128k-capable.

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144, head_dim=256,
local window 512, QK-norm, separate rope bases for local/global layers.
[hf:google/gemma-3-1b-pt]
"""
from repro.configs.base import (ArchConfig, AttentionConfig, ModelConfig,
                                RunConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="gemma3-1b",
        family="dense",
        source="hf:google/gemma-3-1b-pt",
        num_layers=26,              # 26 = 4 groups of (5 local + 1 global) + 2 local
        d_model=1152,
        d_ff=6912,
        vocab_size=262_144,
        act="gelu",
        attention=AttentionConfig(
            kind="local_global",
            num_heads=4,
            num_kv_heads=1,
            head_dim=256,
            window=512,
            local_global_ratio=5,   # 5 local : 1 global
            rope_theta=1_000_000.0, # global layers
            rope_theta_local=10_000.0,
            qk_norm=True,
        ),
        tie_embeddings=True,
        embed_scale=True,
    ),
    run=RunConfig(microbatches=1, remat="layer", max_cache_len=524_288),
)
