"""FL client runtime for the paper's use case: every client trains the
shared GRU on its own sensor's windows.

All clients are trained *batched*: their parameter trees are stacked on a
leading axis and local training is ``vmap``-ed, so one XLA program trains
all 20 clients at once — the CPU-host analogue of the per-pod client
sharding used on the TPU mesh (see fl/collectives.py)."""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import gru

PyTree = Any


class ClientBatch(NamedTuple):
    """Stacked per-client training data: X (C, N, H, 1), y (C, N, 1)."""
    X: jax.Array
    y: jax.Array


def stack_clients(params_list) -> PyTree:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)


def unstack_client(stacked: PyTree, i: int) -> PyTree:
    return jax.tree.map(lambda x: x[i], stacked)


@functools.partial(jax.jit, static_argnames=("cfg", "epochs", "batch_size",
                                             "lr", "max_batches"))
def train_clients_locally(stacked_params: PyTree, data: ClientBatch,
                          rng: jax.Array, *, cfg: ArchConfig,
                          epochs: int, batch_size: int, lr: float,
                          max_batches: int = 0) -> Tuple[PyTree, jax.Array]:
    """Run ``epochs`` of minibatch SGD on every client (vmapped).

    Returns (new stacked params, mean train loss per client (C,))."""
    m = cfg.model
    C, N = data.X.shape[0], data.X.shape[1]
    n_batches = N // batch_size
    if max_batches:
        n_batches = min(n_batches, max_batches)

    def one_client(params, X, y, key):
        def epoch(carry, ekey):
            p, _ = carry
            perm = jax.random.permutation(ekey, N)[:n_batches * batch_size]
            Xb = X[perm].reshape(n_batches, batch_size, *X.shape[1:])
            yb = y[perm].reshape(n_batches, batch_size, *y.shape[1:])

            def step(p2, xy):
                xb, yb_ = xy
                loss, g = jax.value_and_grad(gru.mse_loss)(p2, m, xb, yb_)
                p3 = jax.tree.map(lambda w, gw: w - lr * gw, p2, g)
                return p3, loss

            p, losses = jax.lax.scan(step, p, (Xb, yb))
            return (p, jnp.mean(losses)), None

        keys = jax.random.split(key, epochs)
        (params, last_loss), _ = jax.lax.scan(epoch, (params, 0.0), keys)
        return params, last_loss

    keys = jax.random.split(rng, C)
    return jax.vmap(one_client)(stacked_params, data.X, data.y, keys)


@functools.partial(jax.jit, static_argnames=("cfg",))
def eval_clients(stacked_params: PyTree, data: ClientBatch, *,
                 cfg: ArchConfig) -> jax.Array:
    """Validation MSE per client (C,)."""
    m = cfg.model

    def one(params, X, y):
        return gru.mse_loss(params, m, X, y)

    return jax.vmap(one)(stacked_params, data.X, data.y)
