"""Tiered serving end-to-end: the serving <-> simulation loop closed.

  1. cluster + deploy with a tiered replica pool (the paper's
     "replication for free": device / edge / cloud each keep a model copy)
  2. serve real traffic through the continuous-batching scheduler on the
     edge replica (one-shot prefill, slot reuse, TTFT/TPOT accounting)
  3. measure the engines and run the routing simulator in CALIBRATED mode
     — per-tier service times come from step 2's hardware, not the
     closed-form constant — and compare with the constant paper model

Run:  PYTHONPATH=src python examples/tiered_serving.py
"""
import numpy as np

from repro.orchestration import (DeviceNode, EdgeNode, Inventory,
                                 LearningController)
from repro.routing import SimConfig, compare_methods
from repro.serving import (DEFAULT_TIERS, ContinuousBatchingScheduler,
                           poisson_requests, requests_from_events)

# 1. infrastructure + deployment with serving tiers ------------------------
rng = np.random.default_rng(0)
lam = rng.uniform(2.0, 6.0, 8)
devices = [DeviceNode(i, lam=float(lam[i]), lan_edge=i % 4)
           for i in range(8)]
edges = [EdgeNode(j, capacity_rps=float(lam.sum() / 4 * 1.4))
         for j in range(4)]
controller = LearningController(Inventory(devices, edges), l=2,
                                serving_tiers=DEFAULT_TIERS)
deployment = controller.deploy()
pool = deployment.replica_pool
print("deployed services:",
      [s for s in deployment.inference_services if s.startswith("replica")])

# 2. real traffic through the edge replica's scheduler ---------------------
# (the paper's GRU serves one window per request; use an LM tier to show
# the continuous-batching path)
from repro.serving import ReplicaPool, lm_tiers  # noqa: E402

lm_pool = ReplicaPool(lm_tiers("xlstm-125m"))
engine = lm_pool.engine("edge")
engine.measure(prompt_len=16, decode_steps=4)          # warm compiles
events = poisson_requests(lam, duration_s=1.0, seed=0)
prompts = rng.integers(0, 1024, (len(events), 16))
stats = ContinuousBatchingScheduler(engine).run(
    requests_from_events(events, prompts, max_new_tokens=8))
print(f"edge replica served {len(events)} requests: {stats.summary()}")

# 3. calibrated routing simulation ----------------------------------------
lat = deployment.calibrated_latency()     # GRU pool: one forward/request
inst = controller.inventory.to_instance(l=2)
for name, cfg in (("constant", SimConfig(duration_s=60, seed=0)),
                  ("calibrated", SimConfig(duration_s=60, seed=0,
                                           latency=lat))):
    logs = compare_methods(inst, {"flat": None,
                                  "hflop": deployment.topology.assign}, cfg)
    line = "  ".join(f"{k}={v.mean_latency():.2f}ms"
                     for k, v in logs.items())
    print(f"simulator[{name:10s}]: {line}")
print("per-tier calibrated service times:",
      {t: f"{lat.infer_ms(t):.3f}ms" for t in pool.tiers})
