"""Attention: GQA (full / sliding-window / local:global), DeepSeek MLA,
encoder-decoder cross attention; training/prefill and single-token decode.

Training/prefill attention is *query-chunked* ("lazy flash"): for long
sequences we scan over query chunks so peak memory is O(chunk * S) instead
of O(S^2).  The Pallas flash kernels in ``repro.kernels`` are the TPU hot
path; this module is the XLA path used for CPU execution and dry-run
lowering (selected by config).
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.models.common import ParamBuilder, shard
from repro.models.rope import apply_rope

_NEG_INF = -2.0e38  # fp32-safe mask value
Q_CHUNK_THRESHOLD = 8192
Q_CHUNK = 2048


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_gqa(pb: ParamBuilder, path: str, d_model: int,
             a: AttentionConfig) -> None:
    hd = a.head_dim
    pb.param(f"{path}/wq", (d_model, a.num_heads, hd),
             ("embed", "heads", "head_dim"))
    pb.param(f"{path}/wk", (d_model, a.num_kv_heads, hd),
             ("embed", "kv_heads", "head_dim"))
    pb.param(f"{path}/wv", (d_model, a.num_kv_heads, hd),
             ("embed", "kv_heads", "head_dim"))
    pb.param(f"{path}/wo", (a.num_heads, hd, d_model),
             ("heads", "head_dim", "embed"))
    if a.qk_norm:
        pb.param(f"{path}/q_norm", (hd,), ("head_dim",), init="ones")
        pb.param(f"{path}/k_norm", (hd,), ("head_dim",), init="ones")


def init_mla(pb: ParamBuilder, path: str, d_model: int,
             a: AttentionConfig) -> None:
    m = a.mla
    H = a.num_heads
    pb.param(f"{path}/wq", (d_model, H, m.qk_nope_head_dim + m.qk_rope_head_dim),
             ("embed", "heads", "head_dim"))
    pb.param(f"{path}/w_dkv", (d_model, m.kv_lora_rank), ("embed", "kv_lora"))
    pb.param(f"{path}/w_krope", (d_model, m.qk_rope_head_dim),
             ("embed", "head_dim"))
    pb.param(f"{path}/kv_norm", (m.kv_lora_rank,), ("kv_lora",), init="ones")
    pb.param(f"{path}/w_uk", (m.kv_lora_rank, H, m.qk_nope_head_dim),
             ("kv_lora", "heads", "head_dim"))
    pb.param(f"{path}/w_uv", (m.kv_lora_rank, H, m.v_head_dim),
             ("kv_lora", "heads", "head_dim"))
    pb.param(f"{path}/wo", (H, m.v_head_dim, d_model),
             ("heads", "head_dim", "embed"))


# ---------------------------------------------------------------------------
# Core scaled-dot-product with grouped heads + masking
# ---------------------------------------------------------------------------

def _rms_head_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
          q_pos: jax.Array, k_pos: jax.Array,
          causal: bool, window, soft_cap: float,
          k_valid: Optional[jax.Array] = None) -> jax.Array:
    """q (B,Tq,Hq,D), k/v (B,Tk,Hkv,D'), positions (Tq,)/(Tk,).

    ``window`` may be None, a python int, or a traced scalar (per-layer
    local:global selection inside a homogeneous layer scan).
    Returns (B,Tq,Hq,Dv)."""
    B, Tq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Tq, Hkv, G, D)
    scale = 1.0 / math.sqrt(D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    if soft_cap:
        scores = jnp.tanh(scores / soft_cap) * soft_cap
    d = q_pos[:, None].astype(jnp.int32) - k_pos[None, :].astype(jnp.int32)
    mask = jnp.ones(d.shape, bool) if not causal else (d >= 0)
    if window is not None:
        mask &= d < window
    if k_valid is not None:
        mask &= k_valid[:, None, None, None, :]
        scores = jnp.where(mask, scores, _NEG_INF)
    else:
        scores = jnp.where(mask[None, None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(B, Tq, Hq, v.shape[-1])


def _chunked_sdpa(q, k, v, q_pos, k_pos, causal, window, soft_cap):
    """Scan over query chunks: peak memory O(Q_CHUNK * Tk)."""
    B, Tq, Hq, D = q.shape
    n = Tq // Q_CHUNK
    qs = q.reshape(B, n, Q_CHUNK, Hq, D).transpose(1, 0, 2, 3, 4)
    ps = q_pos.reshape(n, Q_CHUNK)

    def step(_, qc):
        qi, pi = qc
        o = _sdpa(qi, k, v, pi, k_pos, causal, window, soft_cap)
        return None, o

    _, outs = jax.lax.scan(step, None, (qs, ps))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Tq, Hq, v.shape[-1])


def sdpa(q, k, v, q_pos, k_pos, *, causal=True, window=None, soft_cap=0.0,
         k_valid=None):
    big = q.shape[1] >= Q_CHUNK_THRESHOLD and q.shape[1] % Q_CHUNK == 0
    if big and k_valid is None:
        return _chunked_sdpa(q, k, v, q_pos, k_pos, causal, window, soft_cap)
    return _sdpa(q, k, v, q_pos, k_pos, causal, window, soft_cap, k_valid)


# ---------------------------------------------------------------------------
# GQA forward (train / prefill) and decode
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    """Ring-buffer KV cache.  ``k``/``v``: (B, C, Hkv, D); ``pos``: (B, C)
    absolute position of each slot (-1 = empty); ``index``: () next write
    slot (mod C for windowed caches)."""
    k: jax.Array
    v: jax.Array
    pos: jax.Array
    index: jax.Array

    @property
    def capacity(self) -> int:
        return self.k.shape[1]


def init_kv_cache(batch: int, capacity: int, num_kv_heads: int,
                  head_dim: int, dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, capacity, num_kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, capacity, num_kv_heads, head_dim), dtype),
        pos=jnp.full((batch, capacity), -1, jnp.int32),
        index=jnp.zeros((), jnp.int32),
    )


def gqa_forward(p: Dict[str, Any], a: AttentionConfig, x: jax.Array,
                positions: jax.Array, inv_freq: Optional[jax.Array],
                window=None, causal: bool = True,
                kv_source: Optional[jax.Array] = None) -> jax.Array:
    """x (B,S,d).  ``kv_source`` switches to cross-attention (keys/values
    from encoder output; no rope, no causal mask)."""
    src = x if kv_source is None else kv_source
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if a.qk_norm:
        q = _rms_head_norm(q, p["q_norm"])
        k = _rms_head_norm(k, p["k_norm"])
    if kv_source is None:
        k_pos = positions
        if inv_freq is not None:
            q = apply_rope(q, positions, inv_freq)
            k = apply_rope(k, positions, inv_freq)
    else:
        causal = False
        k_pos = jnp.arange(src.shape[1], dtype=jnp.int32)
    q = shard(q, "batch", "seq", "heads_act", None)
    k = shard(k, "batch", "seq", "kv_heads_act", None)
    v = shard(v, "batch", "seq", "kv_heads_act", None)
    out = sdpa(q, k, v, positions, k_pos, causal=causal, window=window)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def gqa_decode(p: Dict[str, Any], a: AttentionConfig, x: jax.Array,
               pos: jax.Array, cache: KVCache,
               inv_freq: Optional[jax.Array], window=None,
               cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
               ) -> Tuple[jax.Array, KVCache]:
    """Single-token decode.  x (B,1,d); pos () absolute position.
    With ``cross_kv`` the cache is ignored (encoder KV precomputed)."""
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if a.qk_norm:
        q = _rms_head_norm(q, p["q_norm"])
    if cross_kv is not None:
        ck, cv = cross_kv
        k_pos = jnp.arange(ck.shape[1], dtype=jnp.int32)
        out = sdpa(q, ck, cv, pos[None], k_pos, causal=False)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if a.qk_norm:
        k = _rms_head_norm(k, p["k_norm"])
    if inv_freq is not None:
        q = apply_rope(q, pos[None][None].repeat(B, 0), inv_freq)
        k = apply_rope(k, pos[None][None].repeat(B, 0), inv_freq)
    slot = cache.index % cache.capacity
    new_cache = KVCache(
        k=jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), slot, axis=1),
        v=jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), slot, axis=1),
        pos=jax.lax.dynamic_update_slice_in_dim(
            cache.pos, jnp.full((B, 1), pos, jnp.int32), slot, axis=1),
        index=cache.index + 1,
    )
    valid = new_cache.pos >= 0
    if window is not None:
        valid &= (pos - new_cache.pos) < window
    # one query vs cache slots; mask by stored absolute positions
    # (cache may be stored quantized, e.g. f8 — upcast for the dot)
    out = _sdpa(q, new_cache.k.astype(q.dtype), new_cache.v.astype(q.dtype),
                pos[None], jnp.zeros((cache.capacity,), jnp.int32),
                causal=False, window=None, soft_cap=a.logit_soft_cap,
                k_valid=valid)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


def _ring_write(buf: jax.Array, new: jax.Array, slots: jax.Array) -> jax.Array:
    """Scatter ``new`` (B,S,...) into ring slots along axis 1; entries whose
    slot index equals the capacity are dropped (pad / out-of-window)."""
    return buf.at[:, slots].set(new.astype(buf.dtype), mode="drop")


def prefill_slots(capacity: int, positions: jax.Array,
                  length: jax.Array) -> jax.Array:
    """Ring slot for each prompt position: the last ``min(length,
    capacity)`` valid positions land at ``pos % capacity``; everything else
    (right padding, positions older than the ring) maps to ``capacity``,
    which ``mode='drop'`` scatters discard."""
    keep = (positions < length) & (positions >= length - capacity)
    return jnp.where(keep, positions % capacity, capacity)


def gqa_prefill(p: Dict[str, Any], a: AttentionConfig, x: jax.Array,
                positions: jax.Array, length: jax.Array, cache: KVCache,
                inv_freq: Optional[jax.Array], window=None,
                ) -> Tuple[jax.Array, KVCache]:
    """Full-sequence prefill: identical math to :func:`gqa_forward` plus a
    one-shot ring write of the roped K/V for positions ``[0, length)``.
    ``x`` may be right-padded beyond ``length``; causality keeps pad keys
    out of every valid query's receptive field."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if a.qk_norm:
        q = _rms_head_norm(q, p["q_norm"])
        k = _rms_head_norm(k, p["k_norm"])
    if inv_freq is not None:
        q = apply_rope(q, positions, inv_freq)
        k = apply_rope(k, positions, inv_freq)
    q = shard(q, "batch", "seq", "heads_act", None)
    k = shard(k, "batch", "seq", "kv_heads_act", None)
    v = shard(v, "batch", "seq", "kv_heads_act", None)
    out = sdpa(q, k, v, positions, positions, causal=True, window=window)
    slots = prefill_slots(cache.capacity, positions, length)
    pos_rows = jnp.broadcast_to(positions[None], (B, S))
    new_cache = KVCache(
        k=_ring_write(cache.k, k, slots),
        v=_ring_write(cache.v, v, slots),
        pos=cache.pos.at[:, slots].set(pos_rows, mode="drop"),
        index=jnp.asarray(length, jnp.int32),
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# Paged KV cache (block-table) variants
# ---------------------------------------------------------------------------

class PagedKVCache(NamedTuple):
    """Paged KV cache shared by all sequences of an engine: ``k_pages`` /
    ``v_pages`` (P+1, page_size, Hkv, D).  Page ids come from the
    :class:`~repro.serving.page_pool.PagePool`; token ``t`` of a sequence
    lives at page ``block_table[t // page_size]`` slot ``t % page_size``.
    The extra page (id P) is a scratch page: free batch rows point their
    whole block table at it so the batched decode write lands somewhere
    harmless."""
    k_pages: jax.Array
    v_pages: jax.Array

    @property
    def page_size(self) -> int:
        return self.k_pages.shape[1]


class PagedMLACache(NamedTuple):
    """Paged compressed-latent cache: ``ckv_pages`` (P+1, page_size, R),
    ``krope_pages`` (P+1, page_size, Dr).  Same scratch-page convention
    as :class:`PagedKVCache`."""
    ckv_pages: jax.Array
    krope_pages: jax.Array

    @property
    def page_size(self) -> int:
        return self.ckv_pages.shape[1]


def init_paged_kv_cache(num_pages: int, page_size: int, num_kv_heads: int,
                        head_dim: int, dtype=jnp.bfloat16) -> PagedKVCache:
    return PagedKVCache(
        k_pages=jnp.zeros((num_pages + 1, page_size, num_kv_heads,
                           head_dim), dtype),
        v_pages=jnp.zeros((num_pages + 1, page_size, num_kv_heads,
                           head_dim), dtype),
    )


def init_paged_mla_cache(num_pages: int, page_size: int, a: AttentionConfig,
                         dtype=jnp.bfloat16) -> PagedMLACache:
    m = a.mla
    return PagedMLACache(
        ckv_pages=jnp.zeros((num_pages + 1, page_size, m.kv_lora_rank),
                            dtype),
        krope_pages=jnp.zeros((num_pages + 1, page_size,
                               m.qk_rope_head_dim), dtype),
    )


def _page_write(pages: jax.Array, new: jax.Array, page_ids: jax.Array,
                slot_ids: jax.Array) -> jax.Array:
    """Scatter ``new`` (B, S, ...) into ``pages`` at (page_ids, slot_ids)
    (both (B, S)); ids equal to ``pages.shape[0]`` are dropped (padding)."""
    return pages.at[page_ids, slot_ids].set(new.astype(pages.dtype),
                                            mode="drop")


def prefill_page_ids(block_tables: jax.Array, positions: jax.Array,
                     length: jax.Array, page_size: int,
                     num_pages: int) -> Tuple[jax.Array, jax.Array]:
    """Page/slot id per prompt position for a one-shot paged prefill
    write.  ``block_tables`` (B, Pseq); ``positions`` (S,).  Positions at
    or past ``length`` (right padding) map to the out-of-bounds page id
    ``num_pages + 1`` so ``mode='drop'`` scatters discard them."""
    B = block_tables.shape[0]
    Pseq = block_tables.shape[1]
    pidx = jnp.clip(positions // page_size, 0, Pseq - 1)
    pages = jnp.take_along_axis(block_tables,
                                jnp.broadcast_to(pidx[None], (B, pidx.shape[0])),
                                axis=1)
    keep = (positions < length) & (positions // page_size < Pseq)
    pages = jnp.where(keep[None], pages, num_pages + 1)
    slots = jnp.broadcast_to((positions % page_size)[None], pages.shape)
    return pages, slots


def paged_gqa_prefill(p: Dict[str, Any], a: AttentionConfig, x: jax.Array,
                      positions: jax.Array, length: jax.Array,
                      cache: PagedKVCache, block_tables: jax.Array,
                      inv_freq: Optional[jax.Array], window=None,
                      ) -> Tuple[jax.Array, PagedKVCache]:
    """Identical attention math to :func:`gqa_prefill`; only the cache
    write differs — K/V scatter through the block table into pages."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if a.qk_norm:
        q = _rms_head_norm(q, p["q_norm"])
        k = _rms_head_norm(k, p["k_norm"])
    if inv_freq is not None:
        q = apply_rope(q, positions, inv_freq)
        k = apply_rope(k, positions, inv_freq)
    out = sdpa(q, k, v, positions, positions, causal=True, window=window)
    ps = cache.page_size
    num_pages = cache.k_pages.shape[0] - 1
    pages, slots = prefill_page_ids(block_tables, positions, length, ps,
                                    num_pages)
    new_cache = PagedKVCache(
        k_pages=_page_write(cache.k_pages, k, pages, slots),
        v_pages=_page_write(cache.v_pages, v, pages, slots),
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


def paged_gqa_decode(p: Dict[str, Any], a: AttentionConfig, x: jax.Array,
                     pos: jax.Array, cache: PagedKVCache,
                     block_tables: jax.Array,
                     inv_freq: Optional[jax.Array], window=None,
                     ) -> Tuple[jax.Array, PagedKVCache]:
    """Batched single-token paged decode.  Unlike :func:`gqa_decode`
    (vmapped per slot over private caches) every row here shares the one
    page array, so ``pos`` is per-row (B,) and the batch advances in one
    program.  Math mirrors :func:`gqa_decode` exactly: same projections,
    rope, ``_sdpa`` mask path — greedy parity with the dense engine."""
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if a.qk_norm:
        q = _rms_head_norm(q, p["q_norm"])
        k = _rms_head_norm(k, p["k_norm"])
    if inv_freq is not None:
        q = apply_rope(q, pos[:, None], inv_freq)
        k = apply_rope(k, pos[:, None], inv_freq)
    ps = cache.page_size
    Pseq = block_tables.shape[1]
    pidx = jnp.take_along_axis(block_tables, (pos // ps)[:, None], axis=1)
    slot = (pos % ps)[:, None]
    new_cache = PagedKVCache(
        k_pages=_page_write(cache.k_pages, k, pidx, slot),
        v_pages=_page_write(cache.v_pages, v, pidx, slot),
    )
    C = Pseq * ps
    kg = new_cache.k_pages[block_tables].reshape(B, C, *k.shape[2:])
    vg = new_cache.v_pages[block_tables].reshape(B, C, *v.shape[2:])
    tok = jnp.arange(C, dtype=jnp.int32)[None, :]
    valid = tok <= pos[:, None]
    if window is not None:
        valid &= (pos[:, None] - tok) < window
    out = _sdpa(q, kg.astype(q.dtype), vg.astype(q.dtype),
                jnp.zeros((1,), jnp.int32), jnp.zeros((C,), jnp.int32),
                causal=False, window=None, soft_cap=a.logit_soft_cap,
                k_valid=valid)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


def paged_mla_prefill(p: Dict[str, Any], a: AttentionConfig, x: jax.Array,
                      positions: jax.Array, length: jax.Array,
                      cache: PagedMLACache, block_tables: jax.Array,
                      inv_freq: Optional[jax.Array],
                      ) -> Tuple[jax.Array, PagedMLACache]:
    """:func:`mla_prefill` math with the latent write paged."""
    m = a.mla
    B, S, _ = x.shape
    H = a.num_heads
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    if inv_freq is not None:
        q_rope = apply_rope(q_rope, positions, inv_freq)
    c_kv, k_rope = _mla_latents(p, a, x, positions, inv_freq)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (B, S, H, m.qk_rope_head_dim))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    out = sdpa(q_full, k_full, v, positions, positions, causal=True)
    ps = cache.page_size
    num_pages = cache.ckv_pages.shape[0] - 1
    pages, slots = prefill_page_ids(block_tables, positions, length, ps,
                                    num_pages)
    new_cache = PagedMLACache(
        ckv_pages=_page_write(cache.ckv_pages, c_kv, pages, slots),
        krope_pages=_page_write(cache.krope_pages, k_rope, pages, slots),
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


def paged_mla_decode(p: Dict[str, Any], a: AttentionConfig, x: jax.Array,
                     pos: jax.Array, cache: PagedMLACache,
                     block_tables: jax.Array,
                     inv_freq: Optional[jax.Array],
                     ) -> Tuple[jax.Array, PagedMLACache]:
    """Absorbed MLA decode over the paged latent cache; per-row ``pos``
    (B,), math mirrors :func:`mla_decode`."""
    m = a.mla
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    if inv_freq is not None:
        q_rope = apply_rope(q_rope, pos[:, None], inv_freq)
    c_new, kr_new = _mla_latents(p, a, x, pos[:, None], inv_freq)
    ps = cache.page_size
    Pseq = block_tables.shape[1]
    pidx = jnp.take_along_axis(block_tables, (pos // ps)[:, None], axis=1)
    slot = (pos % ps)[:, None]
    cache = PagedMLACache(
        ckv_pages=_page_write(cache.ckv_pages, c_new, pidx, slot),
        krope_pages=_page_write(cache.krope_pages, kr_new, pidx, slot),
    )
    C = Pseq * ps
    c_kv = cache.ckv_pages[block_tables].reshape(B, C, -1).astype(x.dtype)
    k_rope = cache.krope_pages[block_tables].reshape(B, C, -1).astype(x.dtype)
    q_c = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s_nope = jnp.einsum("bshr,bcr->bhsc", q_c, c_kv)
    s_rope = jnp.einsum("bshr,bcr->bhsc", q_rope, k_rope)
    scores = (s_nope + s_rope).astype(jnp.float32) * scale
    tok = jnp.arange(C, dtype=jnp.int32)[None, :]
    valid = (tok <= pos[:, None])[:, None, None, :]
    scores = jnp.where(valid, scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhsc,bcr->bshr", probs, c_kv)
    out = jnp.einsum("bshr,rhk->bshk", ctx, p["w_uv"])
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) forward + absorbed decode
# ---------------------------------------------------------------------------

class MLACache(NamedTuple):
    """Compressed KV cache: ``c_kv`` (B,C,R) latents, ``k_rope`` (B,C,Dr)."""
    c_kv: jax.Array
    k_rope: jax.Array
    pos: jax.Array
    index: jax.Array

    @property
    def capacity(self) -> int:
        return self.c_kv.shape[1]


def init_mla_cache(batch: int, capacity: int, a: AttentionConfig,
                   dtype=jnp.bfloat16) -> MLACache:
    m = a.mla
    return MLACache(
        c_kv=jnp.zeros((batch, capacity, m.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, capacity, m.qk_rope_head_dim), dtype),
        pos=jnp.full((batch, capacity), -1, jnp.int32),
        index=jnp.zeros((), jnp.int32),
    )


def _mla_latents(p, a, x, positions, inv_freq):
    m = a.mla
    c_kv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_kv = _rms_head_norm(c_kv, p["kv_norm"])
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["w_krope"])
    if inv_freq is not None:
        k_rope = apply_rope(k_rope[:, :, None, :], positions,
                            inv_freq)[:, :, 0, :]
    return c_kv, k_rope


def mla_forward(p: Dict[str, Any], a: AttentionConfig, x: jax.Array,
                positions: jax.Array, inv_freq: Optional[jax.Array],
                ) -> jax.Array:
    m = a.mla
    B, S, _ = x.shape
    H = a.num_heads
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    if inv_freq is not None:
        q_rope = apply_rope(q_rope, positions, inv_freq)
    c_kv, k_rope = _mla_latents(p, a, x, positions, inv_freq)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])
    # concat nope+rope per head (rope part broadcast across heads)
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (B, S, H, m.qk_rope_head_dim))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    out = sdpa(q_full, k_full, v, positions, positions, causal=True)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def mla_prefill(p: Dict[str, Any], a: AttentionConfig, x: jax.Array,
                positions: jax.Array, length: jax.Array, cache: MLACache,
                inv_freq: Optional[jax.Array],
                ) -> Tuple[jax.Array, MLACache]:
    """Full-sequence MLA prefill: :func:`mla_forward` math plus a one-shot
    write of the compressed latents for positions ``[0, length)``."""
    m = a.mla
    B, S, _ = x.shape
    H = a.num_heads
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    if inv_freq is not None:
        q_rope = apply_rope(q_rope, positions, inv_freq)
    c_kv, k_rope = _mla_latents(p, a, x, positions, inv_freq)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (B, S, H, m.qk_rope_head_dim))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    out = sdpa(q_full, k_full, v, positions, positions, causal=True)
    slots = prefill_slots(cache.capacity, positions, length)
    pos_rows = jnp.broadcast_to(positions[None], (B, S))
    new_cache = MLACache(
        c_kv=_ring_write(cache.c_kv, c_kv, slots),
        k_rope=_ring_write(cache.k_rope, k_rope, slots),
        pos=cache.pos.at[:, slots].set(pos_rows, mode="drop"),
        index=jnp.asarray(length, jnp.int32),
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


def mla_decode(p: Dict[str, Any], a: AttentionConfig, x: jax.Array,
               pos: jax.Array, cache: MLACache,
               inv_freq: Optional[jax.Array]) -> Tuple[jax.Array, MLACache]:
    """Absorbed MLA decode: queries projected into latent space so scores
    are computed against the *compressed* cache directly (beyond-paper
    efficiency; DeepSeek-V2 §"absorption")."""
    m = a.mla
    B = x.shape[0]
    H = a.num_heads
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    if inv_freq is not None:
        q_rope = apply_rope(q_rope, pos[None][None].repeat(B, 0), inv_freq)
    c_new, kr_new = _mla_latents(p, a, x, pos[None][None].repeat(B, 0),
                                 inv_freq)
    slot = cache.index % cache.capacity
    cache = MLACache(
        c_kv=jax.lax.dynamic_update_slice_in_dim(
            cache.c_kv, c_new.astype(cache.c_kv.dtype), slot, 1),
        k_rope=jax.lax.dynamic_update_slice_in_dim(
            cache.k_rope, kr_new.astype(cache.k_rope.dtype), slot, 1),
        pos=jax.lax.dynamic_update_slice_in_dim(
            cache.pos, jnp.full((B, 1), pos, jnp.int32), slot, 1),
        index=cache.index + 1,
    )
    # absorb: q_c[b,h,r] = sum_k q_nope[b,h,k] * w_uk[r,h,k]
    q_c = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    c_kv = cache.c_kv.astype(x.dtype)      # upcast quantized latents
    s_nope = jnp.einsum("bshr,bcr->bhsc", q_c, c_kv)
    s_rope = jnp.einsum("bshr,bcr->bhsc", q_rope,
                        cache.k_rope.astype(x.dtype))
    scores = (s_nope + s_rope).astype(jnp.float32) * scale
    valid = (cache.pos >= 0)[:, None, None, :]
    scores = jnp.where(valid, scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhsc,bcr->bshr", probs, c_kv)
    out = jnp.einsum("bshr,rhk->bshk", ctx, p["w_uv"])
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache
