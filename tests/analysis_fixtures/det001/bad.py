"""DET001 bad fixture: global-state RNG in a sim path."""
import random
from random import choice

import numpy as np


def sample(n):
    x = np.random.rand(n)           # global numpy RNG
    np.random.seed(0)               # global reseed
    return x, random.random(), choice([1, 2])
