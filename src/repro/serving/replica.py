"""Tiered replica pool — the paper's "replication for free" (§III): HFL
leaves a model replica at every tier (device, edge aggregator, cloud), so
serving can dispatch to whichever tier routing selects.

One :class:`ServeEngine` per tier, with per-tier batch sizes (=concurrency
caps) mirroring the hardware asymmetry: a device serves one sequence at a
time, an edge host a handful, the cloud a large batch.  The paper's own
GRU (family ``rnn``) has no token decode loop — each request is one
forward over a history window — so it is served through a jitted
per-request path instead of the slot engine.

``measure()`` produces the per-tier timings that
``LatencyModel.from_measurements`` turns into a calibrated latency model
for the routing simulator (the bridge closing the serving <-> simulation
loop).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import make_model
from repro.serving.engine import (EngineMeasurement, PagedServeEngine,
                                  ServeEngine)

TIERS = ("device", "edge", "cloud")

#: replica health states
HEALTHY, DEGRADED, DOWN = "healthy", "degraded", "down"
HEALTH_STATES = (HEALTHY, DEGRADED, DOWN)

#: failover order: where a tier's traffic goes when its replica is down
#: (up the hierarchy — the cloud is the tier of last resort)
FAILOVER_ORDER: Dict[str, Tuple[str, ...]] = {
    "device": ("edge", "cloud"),
    "edge": ("cloud",),
    "cloud": (),
}


@dataclass(frozen=True)
class TierSpec:
    tier: str                        # device | edge | cloud
    arch: str = "gru-traffic"        # config-registry name
    batch_size: int = 1              # engine rows = concurrency cap
    max_len: int = 256
    reduced: bool = True             # CPU-sized config variant
    replicas: int = 1                # replicas behind this tier
    # paged cache (transformer families only): batch_size rows share a
    # PagePool instead of each reserving a dense max_len cache
    paged: bool = False
    page_size: int = 16
    num_pages: Optional[int] = None  # default: batch_size * ceil(max_len/ps)


# the paper serves ONE model from every tier; the tiers differ in
# concurrency, not in weights
DEFAULT_TIERS: Tuple[TierSpec, ...] = (
    TierSpec("device", batch_size=1),
    TierSpec("edge", batch_size=4),
    TierSpec("cloud", batch_size=16),
)


def lm_tiers(arch: str = "xlstm-125m", max_len: int = 256,
             ) -> Tuple[TierSpec, ...]:
    """Tier layout for a token-decoding LM (benchmarks / examples)."""
    return (TierSpec("device", arch=arch, batch_size=1, max_len=max_len),
            TierSpec("edge", arch=arch, batch_size=4, max_len=max_len),
            TierSpec("cloud", arch=arch, batch_size=8, max_len=max_len))


def paged_lm_tiers(arch: str = "stablelm-1.6b", max_len: int = 256,
                   page_size: int = 16) -> Tuple[TierSpec, ...]:
    """Paged tier layout: each tier keeps the SAME page budget a dense
    tier of ``lm_tiers`` would hold (num_pages defaults to batch_size *
    ceil(max_len / page_size) dense-equivalent pages) but admits by
    actual token footprint, so row counts can be set far above the dense
    slot counts."""
    pages_dense = -(-max_len // page_size)
    return (TierSpec("device", arch=arch, batch_size=4, max_len=max_len,
                     paged=True, page_size=page_size,
                     num_pages=1 * pages_dense),
            TierSpec("edge", arch=arch, batch_size=16, max_len=max_len,
                     paged=True, page_size=page_size,
                     num_pages=4 * pages_dense),
            TierSpec("cloud", arch=arch, batch_size=32, max_len=max_len,
                     paged=True, page_size=page_size,
                     num_pages=8 * pages_dense))


class _RnnReplica:
    """Per-request serving path for the paper's GRU: one jitted forward
    per request batch (the request's unit of work, gru.decode_step)."""

    def __init__(self, cfg, params):
        self.cfg = cfg
        self.params = params
        self.api = make_model(cfg)
        self._fwd = jax.jit(
            lambda p, w: self.api.forward(p, {"windows": w})[0])

    def serve(self, windows: jax.Array) -> jax.Array:
        return self._fwd(self.params, jnp.asarray(windows, jnp.float32))

    def measure(self, batch_size: int, history: int = 12,
                repeats: int = 8, seed: int = 0) -> EngineMeasurement:
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.normal(size=(batch_size, history, 1)),
                        jnp.float32)
        self.serve(w).block_until_ready()          # compile
        t0 = time.perf_counter()
        for _ in range(repeats):
            self.serve(w).block_until_ready()
        ms = (time.perf_counter() - t0) * 1e3 / repeats
        return EngineMeasurement(prefill_ms=ms, decode_ms_per_token=0.0,
                                 batch_size=batch_size, prompt_len=history,
                                 decode_steps=0)


class ReplicaPool:
    """One serving replica per tier, built lazily (constructing engines
    compiles XLA programs — deployments should stay cheap until traffic
    actually arrives at a tier)."""

    def __init__(self, specs: Sequence[TierSpec] = DEFAULT_TIERS,
                 seed: int = 0,
                 shared_params: Optional[Any] = None):
        self.specs: Dict[str, TierSpec] = {}
        for s in specs:
            if s.tier not in TIERS:
                raise ValueError(f"unknown tier {s.tier!r}")
            self.specs[s.tier] = s
        self.seed = seed
        self._shared_params = shared_params
        self._replicas: Dict[str, Any] = {}
        self._health: Dict[str, str] = {t: HEALTHY for t in self.specs}
        self.failovers = 0               # dispatches re-routed off a down tier

    @property
    def tiers(self) -> Tuple[str, ...]:
        return tuple(self.specs)

    def concurrency(self, tier: str) -> int:
        s = self.specs[tier]
        return s.batch_size * s.replicas

    def _build(self, tier: str):
        spec = self.specs[tier]
        cfg = get_config(spec.arch)
        if spec.reduced:
            cfg = cfg.reduced()
        params = self._shared_params
        if params is None:
            api = make_model(cfg)
            # all tiers replicate the SAME trained weights (same seed)
            params, _ = api.init_params(jax.random.key(self.seed))
        if cfg.model.family == "rnn":
            return _RnnReplica(cfg, params)
        if spec.paged:
            return PagedServeEngine(cfg, params, max_seqs=spec.batch_size,
                                    page_size=spec.page_size,
                                    num_pages=spec.num_pages,
                                    max_len=spec.max_len)
        return ServeEngine(cfg, params, batch_size=spec.batch_size,
                           max_len=spec.max_len)

    def replica(self, tier: str):
        if tier not in self._replicas:
            self._replicas[tier] = self._build(tier)
        return self._replicas[tier]

    def engine(self, tier: str) -> ServeEngine:
        rep = self.replica(tier)
        if not isinstance(rep, (ServeEngine, PagedServeEngine)):
            raise TypeError(f"tier {tier!r} serves a per-request model")
        return rep

    # -- health / failover --------------------------------------------------

    def health(self, tier: str) -> str:
        return self._health[tier]

    def set_health(self, tier: str, state: str) -> None:
        if tier not in self.specs:
            raise ValueError(f"unknown tier {tier!r}")
        if state not in HEALTH_STATES:
            raise ValueError(f"unknown health state {state!r}; "
                             f"pick from {HEALTH_STATES}")
        self._health[tier] = state

    def mark_down(self, tier: str) -> List[int]:
        """Crash a tier: drain its engine (in-flight sequences lose
        their cache; paged pools are verified leak-free by
        ``drain``) and stop routing to it until :meth:`mark_up`.
        Returns the drained slot ids so callers can requeue."""
        self.set_health(tier, DOWN)
        rep = self._replicas.get(tier)
        if rep is not None and hasattr(rep, "drain"):
            return rep.drain()
        return []

    def mark_up(self, tier: str) -> None:
        self.set_health(tier, HEALTHY)

    def resolve_tier(self, tier: str) -> str:
        """Failover routing: the requested tier if it can serve (healthy
        or degraded), else the first not-down tier up its
        :data:`FAILOVER_ORDER` chain.  Raises when the whole chain is
        down — there is no silent drop."""
        if self._health.get(tier, DOWN) != DOWN:
            return tier
        for alt in FAILOVER_ORDER.get(tier, ()):
            if alt in self.specs and self._health[alt] != DOWN:
                self.failovers += 1
                return alt
        raise RuntimeError(
            f"tier {tier!r} is down and so is its whole failover chain "
            f"{FAILOVER_ORDER.get(tier, ())}")

    # -- dispatch -----------------------------------------------------------

    def dispatch(self, tier: str, batch, steps: int = 8):
        """Serve one batch on ``tier`` (or its failover target when the
        tier is down — see :meth:`resolve_tier`): token generation for
        LM tiers ((B,S) int prompts -> (B,steps) tokens), a single
        forward for rnn tiers ((B,T,1) windows -> (B,1) predictions)."""
        rep = self.replica(self.resolve_tier(tier))
        if isinstance(rep, _RnnReplica):
            return rep.serve(batch)
        return rep.generate(jnp.asarray(batch, jnp.int32), steps=steps)

    # -- calibration --------------------------------------------------------

    def measure(self, prompt_len: int = 64, decode_steps: int = 16,
                occupancy_levels: Optional[Sequence[int]] = None,
                ) -> Dict[str, EngineMeasurement]:
        """Per-tier wall-clock timings — feed the result to
        ``LatencyModel.from_measurements``.  ``occupancy_levels`` sweeps
        decode time at those admitted-sequence counts per tier (levels a
        tier cannot reach are dropped), giving the latency model real
        high-occupancy points."""
        out = {}
        for tier in self.specs:
            rep = self.replica(tier)
            if isinstance(rep, _RnnReplica):
                out[tier] = rep.measure(self.specs[tier].batch_size)
            else:
                out[tier] = rep.measure(prompt_len=prompt_len,
                                        decode_steps=decode_steps,
                                        occupancy_levels=occupancy_levels)
        return out
