"""Rotary position embeddings (full, partial, dual-base local/global)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rope_frequencies(head_dim: int, theta: float,
                     fraction: float = 1.0) -> np.ndarray:
    """Inverse frequencies for the rotated sub-dimension.

    Returns (rot_dim // 2,) float32 as a *numpy* array (static metadata,
    safe to stack/convert at trace time).  ``fraction`` < 1 rotates only
    the leading ``fraction * head_dim`` dims (stablelm partial rotary)."""
    rot = int(head_dim * fraction)
    rot -= rot % 2
    k = np.arange(rot // 2, dtype=np.float32)
    return (1.0 / (theta ** (2.0 * k / rot))).astype(np.float32)


def apply_rope(x: jax.Array, positions: jax.Array,
               inv_freq: jax.Array) -> jax.Array:
    """Rotate ``x`` (..., seq, heads, head_dim) by ``positions`` (..., seq).

    Only the leading 2*len(inv_freq) dims rotate; the rest pass through.
    """
    rot = 2 * inv_freq.shape[-1]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    ang = positions[..., :, None].astype(jnp.float32) * inv_freq  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., :, None, :]   # broadcast over heads
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    out = jnp.concatenate([out1, out2], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)
