"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time
from typing import Callable, Optional


def time_us(fn: Callable, *args, repeats: int = 5, warmup: int = 1,
            **kwargs) -> float:
    for _ in range(warmup):
        fn(*args, **kwargs)
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn(*args, **kwargs)
    return (time.perf_counter() - t0) / repeats * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
