"""Serving engines: one-shot jitted prefill + continuous-batching decode
over the unified model API.

:class:`ServeEngine` owns a fixed number of *slots* (``batch_size``), each
with a private dense ``max_len`` cache: admission prefills into a slot,
all active slots share ONE jitted decode program (``decode_step`` vmapped
over slots with per-slot positions), so heterogeneous Poisson arrivals
genuinely batch together.  Concurrency is capped by worst-case sequence
length: ``batch_size`` dense caches must fit in HBM whether or not the
sequences use them.

:class:`PagedServeEngine` replaces the per-slot reservation with a shared
:class:`~repro.serving.page_pool.PagePool`: sequences hold
``ceil(tokens / page_size)`` pages, admission is gated on *pages*, decode
extends page-by-page and eviction reclaims.  At equal cache HBM this
lifts max concurrency by roughly ``max_len / (prompt + reserve)`` — the
regime the calibration bridge (``measure`` occupancy sweep →
``LatencyModel.from_measurements``) needs real points in.

The seed token-by-token prompt path is kept as ``generate_sequential`` —
it is the baseline that ``benchmarks/perf_serving_scheduler.py`` measures
the prefill path against.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Deque, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import make_model
from repro.serving.page_pool import PagePool
from repro.telemetry import Telemetry, maybe as _maybe_tel


def bucket_len(n: int, lo: int = 8) -> int:
    """Smallest power-of-two >= n (>= lo): prompts are right-padded to
    buckets so the number of distinct prefill compilations stays
    O(log max_prompt_len)."""
    b = lo
    while b < n:
        b *= 2
    return b


@dataclass(frozen=True)
class EngineMeasurement:
    """Wall-clock engine timings — the raw material for
    ``LatencyModel.from_measurements`` (routing/latency.py)."""
    prefill_ms: float              # one admission of a prompt_len prompt
    decode_ms_per_token: float     # one continuous-batching step
    batch_size: int                # max concurrent sequences
    prompt_len: int
    decode_steps: int
    # occupancy sweep: ((concurrency, decode_ms_per_step), ...) measured
    # at increasing admitted-sequence counts — real high-occupancy points
    # for the latency model instead of extrapolation past batch_size
    occupancy_ms: Tuple[Tuple[int, float], ...] = ()


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params: Any,
                 batch_size: int, max_len: Optional[int] = None,
                 telemetry: Optional[Telemetry] = None):
        self.cfg = cfg
        self._tel = _maybe_tel(telemetry)
        self.api = make_model(cfg)
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len or cfg.run.max_cache_len
        template = self.api.init_cache(1, self.max_len)
        if template is None:
            raise ValueError(
                f"{cfg.name}: family {cfg.model.family!r} has no decode "
                "cache — serve it per-request via ReplicaPool instead")
        # per-slot cache: every leaf gains a leading slot axis, and each
        # slot keeps its own ring index / positions
        self._slot_template = template
        self.cache = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (batch_size,) + x.shape),
            template)
        self.pos = jnp.zeros((batch_size,), jnp.int32)
        self.next_tok = jnp.zeros((batch_size, 1, 1), jnp.int32)
        self.free_slots: Deque[int] = deque(range(batch_size))
        self._free_set: Set[int] = set(range(batch_size))

        self._decode = jax.jit(
            jax.vmap(self._slot_decode, in_axes=(None, 0, 0, 0)))
        self._prefill = jax.jit(self._prefill_impl)
        self._insert = jax.jit(self._insert_impl)
        self._seq_decode = jax.jit(self._seq_decode_impl)

    # -- jitted programs ----------------------------------------------------

    def _slot_decode(self, params, tok, pos, cache):
        """One decode step for one slot (vmapped over slots)."""
        logits, cache = self.api.decode_step(params, tok, pos, cache)
        return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32), cache

    def _prefill_impl(self, params, tokens, length, cache):
        """tokens (1, S_bucket) right-padded; length () valid tokens.
        Returns (first generated token (1,), prefilled cache)."""
        if self.api.prefill is not None:
            logits, cache = self.api.prefill(params, tokens, cache,
                                             length=length)
            last = logits[:, length - 1, :]
        else:
            # recurrent families: fused scan over decode steps — still ONE
            # program per bucket instead of S python-level dispatches
            S = tokens.shape[1]
            toks = tokens.T[:, :, None]                  # (S, 1, 1)
            ts = jnp.arange(S, dtype=jnp.int32)

            def body(c, xs):
                tok, t = xs
                logits, new_c = self.api.decode_step(params, tok, t, c)
                keep = t < length
                c = jax.tree.map(lambda n, o: jnp.where(keep, n, o),
                                 new_c, c)
                return c, logits[:, -1, :]

            cache, ys = jax.lax.scan(body, cache, (toks, ts))
            last = ys[length - 1]
        return jnp.argmax(last, axis=-1).astype(jnp.int32), cache

    def _insert_impl(self, cache, new, slot):
        return jax.tree.map(lambda c, n: c.at[slot].set(n), cache, new)

    def _seq_decode_impl(self, params, tokens, pos, cache):
        logits, cache = self.api.decode_step(params, tokens, pos, cache)
        return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32), cache

    # -- slot management ----------------------------------------------------

    def acquire_slot(self) -> Optional[int]:
        if not self.free_slots:
            return None
        slot = self.free_slots.popleft()
        self._free_set.discard(slot)
        return slot

    def can_admit(self, prompt_len: int, max_new_tokens: int = 0) -> bool:
        """Dense admission is slot-gated only: every slot already owns a
        worst-case ``max_len`` cache."""
        return bool(self.free_slots)

    def admit(self, prompt, slot: int,
              reserve_tokens: Optional[int] = None) -> int:
        """Prefill ``prompt`` (S,) into ``slot``.  Returns the first
        generated (greedy) token.  ``reserve_tokens`` is accepted for
        signature parity with :class:`PagedServeEngine` (a dense slot
        always reserves ``max_len``)."""
        if self._tel is not None:
            with self._tel.tracer.wall("serve.admit", cat="serving",
                                       slot=int(slot)):
                first = self._admit_impl(prompt, slot)
            self._tel.metrics.counter("serve.admissions").inc()
            return first
        return self._admit_impl(prompt, slot)

    def _admit_impl(self, prompt, slot: int) -> int:
        prompt = jnp.asarray(prompt, jnp.int32).reshape(1, -1)
        S = prompt.shape[1]
        if S > self.max_len:
            raise ValueError(f"prompt ({S}) exceeds max_len {self.max_len}")
        Sb = bucket_len(S)
        padded = jnp.zeros((1, Sb), jnp.int32).at[:, :S].set(prompt)
        first, slot_cache = self._prefill(self.params, padded,
                                          jnp.int32(S), self._slot_template)
        self.cache = self._insert(self.cache, slot_cache, jnp.int32(slot))
        self.pos = self.pos.at[slot].set(S)
        self.next_tok = self.next_tok.at[slot, 0, 0].set(first[0])
        if slot in self._free_set:
            self._free_set.discard(slot)
            self.free_slots.remove(slot)
        return int(first[0])

    def evict(self, slot: int) -> None:
        """Release a slot.  Its stale cache is simply overwritten by the
        next admission — no device work.  Double eviction raises: a slot
        freed twice means two sequences believed they owned it."""
        if slot in self._free_set:
            raise ValueError(f"slot {slot} is already free (double evict)")
        self.free_slots.append(slot)
        self._free_set.add(slot)
        if self._tel is not None:
            self._tel.metrics.counter("serve.evictions").inc()

    def drain(self) -> List[int]:
        """Crash recovery: evict every live slot at once (their cache
        contents are considered lost).  Returns the drained slot ids so
        the scheduler can requeue the corresponding requests."""
        drained = [s for s in range(self.batch_size)
                   if s not in self._free_set]
        for slot in drained:
            self.evict(slot)
        return drained

    @property
    def active_slots(self) -> int:
        return self.batch_size - len(self.free_slots)

    # -- decode -------------------------------------------------------------

    def decode(self) -> np.ndarray:
        """One continuous-batching step: every slot advances one token
        under its own position.  Returns (batch_size,) token ids (entries
        for free slots are meaningless)."""
        toks, self.cache = self._decode(self.params, self.next_tok,
                                        self.pos, self.cache)
        self.pos = self.pos + 1
        self.next_tok = toks[:, :, None]
        if self._tel is not None:
            self._tel.metrics.counter("serve.decode_steps").inc()
        return np.asarray(toks[:, 0])

    # -- convenience generation paths --------------------------------------

    def generate(self, prompt_tokens: jax.Array, steps: int) -> jax.Array:
        """Greedy generation via prefill + continuous-batching decode.
        Returns (B, steps) — same contract as the seed engine.

        Requires an idle engine: ``decode`` advances *every* slot, so
        interleaving ``generate`` with externally managed sequences would
        silently consume their tokens.  Mixed workloads go through
        ``ContinuousBatchingScheduler`` instead."""
        B, S = prompt_tokens.shape
        if B > self.batch_size:
            raise ValueError(f"batch {B} exceeds {self.batch_size} slots")
        if self.active_slots:
            raise RuntimeError(
                "engine has active sequences; drive mixed workloads "
                "through ContinuousBatchingScheduler")
        slots = [self.acquire_slot() for _ in range(B)]
        first = [self.admit(prompt_tokens[b], slot=s)
                 for b, s in enumerate(slots)]
        out = [np.asarray(first, np.int32)]
        for _ in range(steps - 1):
            toks = self.decode()
            out.append(toks[np.asarray(slots)])
        for s in slots:
            self.evict(s)
        return jnp.asarray(np.stack(out, axis=1))

    def generate_sequential(self, prompt_tokens: jax.Array,
                            steps: int) -> jax.Array:
        """The seed path: feeds the prompt token-by-token (S sequential
        decode dispatches) then samples ``steps`` continuations.  Kept as
        the baseline for the prefill speedup benchmark."""
        B, S = prompt_tokens.shape
        cache = self.api.init_cache(B, self.max_len)
        tok = None
        for s in range(S):
            tok, cache = self._seq_decode(self.params,
                                          prompt_tokens[:, s:s + 1],
                                          jnp.int32(s), cache)
        out = []
        for t in range(steps):
            out.append(tok)
            tok, cache = self._seq_decode(self.params, tok[:, None],
                                          jnp.int32(S + t), cache)
        return jnp.stack(out, axis=1)

    # -- calibration --------------------------------------------------------

    def measure(self, prompt_len: int = 64, decode_steps: int = 16,
                seed: int = 0,
                occupancy_levels: Optional[Sequence[int]] = None,
                ) -> EngineMeasurement:
        """Measure wall-clock prefill and continuous-batching step times
        (after a warmup pass that triggers compilation).  With
        ``occupancy_levels`` also sweeps decode step time at increasing
        admitted-sequence counts (levels above the slot budget are
        skipped).

        Safe to call mid-serving: the engine's slot state (caches,
        positions, pending tokens) is snapshotted before and restored
        after, so in-flight sequences resume exactly where they were —
        the measurement decodes never reach them."""
        if self._tel is not None:
            with self._tel.tracer.wall("serve.measure", cat="serving",
                                       prompt_len=int(prompt_len),
                                       decode_steps=int(decode_steps)):
                return self._measure_impl(prompt_len, decode_steps, seed,
                                          occupancy_levels)
        return self._measure_impl(prompt_len, decode_steps, seed,
                                  occupancy_levels)

    def _measure_impl(self, prompt_len: int, decode_steps: int, seed: int,
                      occupancy_levels) -> EngineMeasurement:
        saved = (self.cache, self.pos, self.next_tok,
                 list(self.free_slots))
        rng = np.random.default_rng(seed)
        vocab = max(self.cfg.model.vocab_size, 2)
        prompt = rng.integers(0, vocab, (prompt_len,))
        slot = self.free_slots[0] if self.free_slots else 0
        try:
            self.admit(prompt, slot=slot)        # warmup: compile prefill
            self.decode()                        # warmup: compile decode
            t0 = time.perf_counter()
            self.admit(prompt, slot=slot)
            prefill_ms = (time.perf_counter() - t0) * 1e3
            t0 = time.perf_counter()
            for _ in range(decode_steps):
                self.decode()
            decode_ms = (time.perf_counter() - t0) * 1e3 \
                / max(decode_steps, 1)
            sweep = self._occupancy_sweep(occupancy_levels, prompt,
                                          decode_steps)
        finally:
            self.cache, self.pos, self.next_tok = saved[:3]
            self.free_slots = deque(saved[3])
            self._free_set = set(saved[3])
        return EngineMeasurement(prefill_ms=prefill_ms,
                                 decode_ms_per_token=decode_ms,
                                 batch_size=self.batch_size,
                                 prompt_len=prompt_len,
                                 decode_steps=decode_steps,
                                 occupancy_ms=sweep)

    def _occupancy_sweep(self, levels, prompt,
                         decode_steps: int) -> Tuple[Tuple[int, float], ...]:
        """Admit up to each requested concurrency level and time decode
        steps there.  Shared by both engines: only ``can_admit`` differs
        (slots vs pages), which is exactly the boundary the sweep probes."""
        if not levels:
            return ()
        out = []
        for lvl in sorted(set(int(v) for v in levels)):
            while self.active_slots < lvl \
                    and self.can_admit(len(prompt), decode_steps):
                s = self.acquire_slot()
                if s is None:
                    break
                self.admit(prompt, slot=s, reserve_tokens=decode_steps)
            if self.active_slots < lvl:
                break                       # slot/page budget exhausted
            t0 = time.perf_counter()
            for _ in range(decode_steps):
                self.decode()
            ms = (time.perf_counter() - t0) * 1e3 / max(decode_steps, 1)
            out.append((lvl, ms))
        return tuple(out)


class PagedServeEngine:
    """Continuous batching over a shared paged cache.

    Rows (``max_seqs`` of them) are just batch positions in the single
    batched decode program; the cache behind them is a page pool shared
    by every live sequence.  Admission allocates ``prompt_len +
    reserve_tokens`` worth of pages (raising the effective concurrency to
    however many *actual* tokens fit, instead of ``HBM / max_len``),
    decode extends page-by-page as sequences cross page boundaries, and
    eviction returns pages to the pool.

    Free rows point their whole block table at a scratch page (id
    ``num_pages`` — the page arrays carry one extra page for this) so the
    batched write lands somewhere harmless; their outputs are ignored.

    Greedy outputs are token-for-token identical to :class:`ServeEngine`:
    the paged attention math mirrors the dense path exactly (same
    projections, rope, mask, softmax — only the cache addressing
    differs)."""

    def __init__(self, cfg: ArchConfig, params: Any, max_seqs: int,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 max_len: Optional[int] = None, reserve_tokens: int = 16,
                 telemetry: Optional[Telemetry] = None):
        self.cfg = cfg
        self._tel = _maybe_tel(telemetry)
        self.api = make_model(cfg)
        if self.api.paged_prefill is None:
            raise ValueError(
                f"{cfg.name}: family {cfg.model.family!r} has no paged "
                "cache path (recurrent state is O(1) per sequence — use "
                "ServeEngine)")
        self.params = params
        self.batch_size = max_seqs        # scheduler-facing name
        self.max_seqs = max_seqs
        self.max_len = max_len or cfg.run.max_cache_len
        self.page_size = int(page_size)
        self.pages_per_seq = -(-self.max_len // self.page_size)
        # default budget = what ONE dense slot-engine of the same
        # (max_seqs, max_len) would reserve, so paged-vs-dense comparisons
        # at equal HBM are the default configuration
        self.num_pages = int(num_pages or max_seqs * self.pages_per_seq)
        self.reserve_tokens = int(reserve_tokens)
        self.pool = PagePool(self.num_pages, self.page_size,
                             telemetry=telemetry)
        self.cache = self.api.init_paged_cache(self.num_pages,
                                               self.page_size)
        self.scratch_page = self.num_pages
        self._block_tables = np.full((max_seqs, self.pages_per_seq),
                                     self.scratch_page, np.int32)
        self._pos = np.zeros((max_seqs,), np.int32)
        self._next_tok = np.zeros((max_seqs, 1), np.int32)
        self.free_slots: Deque[int] = deque(range(max_seqs))
        self._free_set: Set[int] = set(range(max_seqs))

        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl)

    # -- jitted programs ----------------------------------------------------

    def _decode_impl(self, params, toks, pos, cache, block_tables):
        logits, cache = self.api.paged_decode_step(params, toks, pos,
                                                   cache, block_tables)
        return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32), cache

    def _prefill_impl(self, params, tokens, length, cache, block_table):
        logits, cache = self.api.paged_prefill(params, tokens, cache,
                                               block_table, length=length)
        last = logits[:, length - 1, :]
        return jnp.argmax(last, axis=-1).astype(jnp.int32), cache

    # -- admission ----------------------------------------------------------

    def acquire_slot(self) -> Optional[int]:
        if not self.free_slots:
            return None
        slot = self.free_slots.popleft()
        self._free_set.discard(slot)
        return slot

    def can_admit(self, prompt_len: int, max_new_tokens: int = 0) -> bool:
        """True when a row is free AND the pool can hold the prompt plus
        the decode reservation."""
        need = prompt_len + max(int(max_new_tokens), self.reserve_tokens)
        return bool(self.free_slots) and self.pool.can_allocate(need)

    def admit(self, prompt, slot: int,
              reserve_tokens: Optional[int] = None) -> int:
        """Allocate pages for ``prompt`` plus ``reserve_tokens`` of decode
        headroom (engine default when None), prefill through the block
        table, return the first greedy token.  Raises
        :class:`~repro.serving.page_pool.PagesExhausted` when the pool
        cannot hold the sequence."""
        if self._tel is not None:
            with self._tel.tracer.wall("serve.admit", cat="serving",
                                       slot=int(slot)):
                first = self._admit_impl(prompt, slot, reserve_tokens)
            self._tel.metrics.counter("serve.admissions").inc()
            return first
        return self._admit_impl(prompt, slot, reserve_tokens)

    def _admit_impl(self, prompt, slot: int,
                    reserve_tokens: Optional[int]) -> int:
        prompt = jnp.asarray(prompt, jnp.int32).reshape(1, -1)
        S = prompt.shape[1]
        if S > self.max_len:
            raise ValueError(f"prompt ({S}) exceeds max_len {self.max_len}")
        reserve = self.reserve_tokens if reserve_tokens is None \
            else int(reserve_tokens)
        reserved = min(S + max(reserve, 1), self.max_len)
        table = self.pool.allocate(slot, reserved)
        try:
            row = np.full((self.pages_per_seq,), self.scratch_page,
                          np.int32)
            row[:len(table)] = table
            self._block_tables[slot] = row
            Sb = bucket_len(S)
            padded = jnp.zeros((1, Sb), jnp.int32).at[:, :S].set(prompt)
            first, self.cache = self._prefill(
                self.params, padded, jnp.int32(S), self.cache,
                jnp.asarray(row[None]))
        except BaseException:
            # allocation succeeded but prefill didn't: hand the pages
            # back, or every failed admission leaks a block table
            self.pool.release(slot)
            self._block_tables[slot] = self.scratch_page
            raise
        self._pos[slot] = S
        self._next_tok[slot, 0] = int(first[0])
        if slot in self._free_set:
            self._free_set.discard(slot)
            self.free_slots.remove(slot)
        return int(first[0])

    def evict(self, slot: int) -> None:
        """Return the row's pages to the pool.  Double eviction raises —
        silently re-freeing would hand the same pages to two sequences.
        A row whose admission failed mid-prefill holds no pages (they
        were released on the error path); evicting it just frees the
        row."""
        if slot in self._free_set:
            raise ValueError(f"slot {slot} is already free (double evict)")
        if slot in self.pool.sequences:
            self.pool.release(slot)
        self._block_tables[slot] = self.scratch_page
        self._pos[slot] = 0
        self._next_tok[slot] = 0
        self.free_slots.append(slot)
        self._free_set.add(slot)
        if self._tel is not None:
            self._tel.metrics.counter("serve.evictions").inc()

    def drain(self) -> List[int]:
        """Crash recovery: evict every live row, returning all their
        pages to the pool, and verify the pool comes back whole
        (invariants hold and every page is free again).  Returns the
        drained slot ids so the scheduler can requeue the requests."""
        drained = [s for s in range(self.max_seqs)
                   if s not in self._free_set]
        for slot in drained:
            self.evict(slot)
        self.pool.check_invariants()
        if self.pool.free_pages != self.num_pages:
            raise RuntimeError(
                f"page leak after drain: {self.pool.free_pages} free of "
                f"{self.num_pages}")
        return drained

    @property
    def active_slots(self) -> int:
        return self.max_seqs - len(self.free_slots)

    # -- decode -------------------------------------------------------------

    def decode(self) -> np.ndarray:
        """One continuous-batching step: every live row advances one
        token through the shared paged cache in a single program.
        Extends page allocations for rows whose next token crosses their
        reservation (raises ``PagesExhausted`` if the pool is dry — gate
        admissions with ``can_admit(prompt_len, max_new_tokens)`` to
        guarantee completion headroom).  Returns (max_seqs,) token ids
        (free-row entries are meaningless)."""
        for slot in range(self.max_seqs):
            if slot in self._free_set:
                continue
            needed = int(self._pos[slot]) + 1
            if needed > self.pool.length(slot):
                self.pool.extend(slot, needed)
                table = self.pool.block_table(slot)
                self._block_tables[slot, :len(table)] = table
        toks, self.cache = self._decode(
            self.params, jnp.asarray(self._next_tok),
            jnp.asarray(self._pos), self.cache,
            jnp.asarray(self._block_tables))
        toks = np.asarray(toks)
        for slot in range(self.max_seqs):
            if slot not in self._free_set:
                self._pos[slot] += 1
                self._next_tok[slot, 0] = toks[slot]
        if self._tel is not None:
            self._tel.metrics.counter("serve.decode_steps").inc()
        return toks

    # -- convenience generation ---------------------------------------------

    def generate(self, prompt_tokens: jax.Array, steps: int) -> jax.Array:
        """Greedy generation — same contract and token stream as
        :meth:`ServeEngine.generate`."""
        B, S = prompt_tokens.shape
        if B > self.max_seqs:
            raise ValueError(f"batch {B} exceeds {self.max_seqs} rows")
        if self.active_slots:
            raise RuntimeError(
                "engine has active sequences; drive mixed workloads "
                "through ContinuousBatchingScheduler")
        slots = [self.acquire_slot() for _ in range(B)]
        first = [self.admit(prompt_tokens[b], slot=s, reserve_tokens=steps)
                 for b, s in enumerate(slots)]
        out = [np.asarray(first, np.int32)]
        for _ in range(steps - 1):
            toks = self.decode()
            out.append(toks[np.asarray(slots)])
        for s in slots:
            self.evict(s)
        return jnp.asarray(np.stack(out, axis=1))

    # -- calibration --------------------------------------------------------

    measure = ServeEngine.measure
    _occupancy_sweep = ServeEngine._occupancy_sweep

    def _measure_impl(self, prompt_len: int, decode_steps: int, seed: int,
                      occupancy_levels) -> EngineMeasurement:
        saved = (self.cache, self._pos.copy(), self._next_tok.copy(),
                 self._block_tables.copy(), list(self.free_slots),
                 self.pool.snapshot())
        rng = np.random.default_rng(seed)
        vocab = max(self.cfg.model.vocab_size, 2)
        prompt = rng.integers(0, vocab, (prompt_len,))
        try:
            slot = self.acquire_slot()
            if slot is None:
                raise RuntimeError("measure() needs at least one free row")
            self.admit(prompt, slot=slot,
                       reserve_tokens=decode_steps)     # warmup prefill
            self.decode()                               # warmup decode
            self.evict(slot)
            slot = self.acquire_slot()
            t0 = time.perf_counter()
            self.admit(prompt, slot=slot, reserve_tokens=decode_steps)
            prefill_ms = (time.perf_counter() - t0) * 1e3
            t0 = time.perf_counter()
            for _ in range(decode_steps):
                self.decode()
            decode_ms = (time.perf_counter() - t0) * 1e3 \
                / max(decode_steps, 1)
            sweep = self._occupancy_sweep(occupancy_levels, prompt,
                                          decode_steps)
        finally:
            self.cache = saved[0]
            self._pos, self._next_tok, self._block_tables = saved[1:4]
            self.free_slots = deque(saved[4])
            self._free_set = set(saved[4])
            self.pool.restore(saved[5])
        return EngineMeasurement(prefill_ms=prefill_ms,
                                 decode_ms_per_token=decode_ms,
                                 batch_size=self.max_seqs,
                                 prompt_len=prompt_len,
                                 decode_steps=decode_steps,
                                 occupancy_ms=sweep)
