"""Flash-decode Pallas kernel: ONE query token against a blocked KV cache
with online softmax over key blocks — the hot loop of ``decode_32k`` /
``long_500k`` serving.

Grid: (batch, kv_head, C/bk).  The query's G=H/Hkv grouped heads are kept
together in VMEM so each cache block is read once per kv_head (GQA makes
decode memory-bound; minimizing cache reads is the whole game)."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, bk: int, scale: float):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)            # (bk, D)
    v = v_ref[0, :, 0].astype(jnp.float32)            # (bk, Dv)
    ok = valid_ref[0]                                 # (bk,)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(ok[None, :], s, NEG_INF)            # (G, bk)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(ic == nc - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid: jax.Array, *, bk: int = 512,
                     interpret: bool = True) -> jax.Array:
    """q (B,H,D); k/v (B,C,Hkv,D); valid (B,C) bool -> (B,H,Dv)."""
    B, H, D = q.shape
    C, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // Hkv
    bk = min(bk, C)
    assert C % bk == 0
    qg = q.reshape(B, Hkv, G, D)
    grid = (B, Hkv, C // bk)
    kernel = functools.partial(_decode_kernel, bk=bk,
                               scale=1.0 / math.sqrt(D))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, bk, 1, Dv), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, bk), lambda b, h, c: (b, c)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv), lambda b, h, c: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, Dv), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, valid)
    return out.reshape(B, H, Dv)
