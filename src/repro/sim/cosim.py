"""Unified training–inference co-simulation.

Runs continual HFL training rounds and inference serving on the *same*
per-node compute timeline: the round schedule (``fl.hierarchy.
round_schedule``) becomes typed events on the shared event core, each
participating device's local epochs mark it busy (rule R1 offloads its
requests) and claim compute, aggregation uploads occupy the edges (and
the cloud on global rounds), and the interference model stretches
service times for whatever the node still serves.  Inference requests
ride the same heap via the ``RequestProcessor`` that also powers the
inference-only ``routing.simulator``.

An optional reactive loop (``sim.reactive.ReactiveLoop``) watches the
telemetry this engine emits and drives the learning controller's
``on_node_failure`` / ``on_capacity_change`` / ``on_accuracy_alarm``
hooks mid-simulation, swapping re-clustered deployments back in with a
modeled replica-migration cost.

Determinism: all randomness flows through one ``np.random.Generator``
seeded from ``CoSimConfig.seed`` (device speed factors first, then the
arrival streams, then per-request RTT draws in arrival order), so the
same seed yields an identical event trace and request log.

Engines: the heap carries only the sparse *control plane* (round /
epoch / aggregation windows, failures, moves, stragglers, tenant load,
drift, reconfig, telemetry).  With the default ``engine="batched"``
the dense *request plane* is processed in vectorized batches over the
windows between control events (``repro.sim.request_plane``); with
``engine="heap"`` every request rides the heap as two events — the
parity reference.  Routing and service are deterministic here and the
batched RTT draws consume the generator stream in heap order, so the
two engines produce **bit-identical** request logs, reactions and
control traces for the same seed (asserted in
``tests/test_event_engine.py``; admission arithmetic agrees up to a
measure-zero threshold-coincidence caveat — see
``request_plane.bucket_admissions``); only wall-clock differs.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.topology import ClusterTopology
from repro.fl.schedule import RoundWindow
from repro.routing.latency import LatencyModel
from repro.routing.rules import EdgeState, RouteDecision
from repro.routing.simulator import RequestLog, RequestProcessor
from repro.serving.workload import poisson_request_arrays
from repro.sim.budget import ReconfigBudget
from repro.sim.budget import BudgetEntry
from repro.sim.events import Event, EventKind, Simulation
from repro.sim.interference import InterferenceConfig, InterferenceModel
from repro.sim.request_plane import TIER_DEVICE
from repro.telemetry import Telemetry, maybe as _maybe_tel

# interference-demand source-name prefixes for load that is *external*
# to the training pipeline — it survives the edge-tier rebuild on a
# re-deploy (a tenant job doesn't vanish because HFL re-clustered)
EXTERNAL_DEMAND_PREFIXES = ("tenant:", "handover:")


@dataclass
class CoSimConfig:
    duration_s: float = 300.0
    seed: int = 0
    rate_scale: float = 1.0
    latency: LatencyModel = field(default_factory=LatencyModel)
    interference: InterferenceConfig = field(
        default_factory=InterferenceConfig)
    speed_spread: float = 0.3        # device heterogeneity: fastest device
    #                                  runs an epoch in (1-spread) x nominal
    telemetry_s: float = 2.0         # reactive monitor tick period
    reconfig_s: float = 5.0          # replica migration duration
    reconfig_penalty_ms: float = 25.0  # per-request cost while migrating
    handover_s: float = 3.0          # device-mobility handover duration
    handover_penalty_ms: float = 15.0  # per-request cost while handing over
    record_trace: bool = True
    engine: str = "batched"          # "batched" | "heap" (parity)
    fuse_windows: bool = True        # fuse request-plane windows across
    #                                  effect-free control events (trace-
    #                                  equivalent; False = flush at every
    #                                  control event, the pre-fusion path)
    telemetry: Optional[Telemetry] = None  # metrics/spans/audit sink;
    #                                  pure observation — event ordering,
    #                                  RNG streams, logs and fingerprints
    #                                  are bit-identical with or without


@dataclass
class CoSimResult:
    log: RequestLog
    trace: List[Tuple[float, str, int]]
    rounds_completed: int
    reconfig_times: List[float]
    mse_series: np.ndarray           # (k, 2) [t, modeled val MSE]
    actions: List[Tuple[float, str]]  # reactive-loop decisions
    budget: Optional[ReconfigBudget] = None  # reconfig accountant, if any
    drop_log: List[Tuple[float, int, int, int]] = field(
        default_factory=list)        # (t, device, round idx, epochs dropped)
    move_log: List[Tuple[float, int, int, int]] = field(
        default_factory=list)        # (t, device, old edge, new edge)
    fault_stats: Dict[str, int] = field(default_factory=dict)
    #                                  chaos accounting: attempts failed,
    #                                  retries, failovers, promotions, ...


class CoSim:
    """One co-simulation run over a topology.  ``schedule`` is the
    training timeline (None -> serving only); ``reactive`` an optional
    ``ReactiveLoop`` bound to a ``LearningController``."""

    def __init__(self, topo: ClusterTopology, cfg: CoSimConfig,
                 schedule: Optional[Sequence[RoundWindow]] = None,
                 reactive=None, budget: Optional[ReconfigBudget] = None):
        self.cfg = cfg
        self.sim = Simulation(record_trace=cfg.record_trace,
                              fuse_windows=cfg.fuse_windows)
        self.sim.flush_gate = self._flush_gate
        self.tel = _maybe_tel(cfg.telemetry)
        self.rng = np.random.default_rng(cfg.seed)
        n = topo.n_devices
        # per-device epoch-time multiplier in [1-spread, 1]: every device
        # finishes its local epochs by the round's nominal compute_end
        self.speed = 1.0 - cfg.speed_spread * self.rng.random(n)
        self.interference = InterferenceModel(cfg.latency, cfg.interference)
        self.proc = RequestProcessor(
            topo, self.rng, latency=cfg.latency, busy_fn=self._busy,
            service_fn=self.interference.service_ms,
            extra_ms_fn=self._request_penalty,
            engine=cfg.engine,
            busy_mask_fn=self._busy_mask,
            stretch_fn=self.interference.stretch_array,
            extra_ms_vec_fn=self._request_penalty_vec,
            telemetry=cfg.telemetry)
        self.proc.bind(self.sim)

        self._busy_count = np.zeros(n, dtype=int)
        self._epochs_left: Dict[Tuple[int, int], np.ndarray] = {}
        # per-window per-device epoch plan [(start, end, token), ...]
        # so a STRAGGLER can re-time the epochs that have not started yet
        self._epoch_sched: Dict[Tuple[int, int],
                                Tuple[RoundWindow,
                                      Dict[int, List[List]]]] = {}
        self._cancelled: Set[int] = set()   # tokens of re-timed epochs
        self._tok = 0
        self._straggler_info: Dict[int, List[Tuple[int, RoundWindow,
                                                   float]]] = {}
        self._handover_until = np.full(n, -math.inf)
        # injection-time edge id -> current topology id (None once the
        # host is gone).  Scheduled events (moves, tenant jobs,
        # failures) name edges as they were numbered when scheduled; a
        # failure-driven recluster renumbers the topology, and the
        # reactive loop composes that shift into this alias so pending
        # events keep landing on the same physical host (or are dropped
        # when it is dead).
        self.edge_alias: Dict[int, Optional[int]] = {
            j: j for j in range(topo.n_edges)}
        self._active_rounds = 0
        self._active_aggs: Set[Tuple[int, int]] = set()
        self._sched_count = 0
        # chaos subsystem (repro.sim.faults): inert until
        # schedule_faults arms it — no draws, no events, no branches on
        # the request path, so fingerprints stay bit-identical to a
        # fault-free build (tests/test_faults.py pins this)
        self._faults_armed = False
        self._standby_enabled = True
        self.quorum = 0.0                # min fraction of devices whose
        #                                  edge is up for round credit
        self.max_stale_rounds = 2        # staleness bound: consecutive
        #                                  below-quorum rounds tolerated
        self.stale_rounds = 0
        self.rounds_below_quorum = 0
        self.stale_bound_exceeded = 0
        self.last_round_quorum_ok = True
        self.standby_promotions = 0
        # fault-window bookkeeping: widx -> (kind, param, resolved edge
        # ids at start time); standby snapshots per widx for restore
        self._active_faults: Dict[int, Tuple[str, float, Tuple[int, ...]]]\
            = {}
        self._standby: Dict[int, List[Tuple[int, int, np.ndarray]]] = {}
        self.fault_log: List[Tuple[float, str, str,
                                   Tuple[int, ...]]] = []
        self.rounds_completed = 0
        self.last_round_end = -math.inf
        self.reconfig_until = -math.inf
        self.reconfig_times: List[float] = []
        self.drop_log: List[Tuple[float, int, int, int]] = []
        self.move_log: List[Tuple[float, int, int, int]] = []
        self.tenant_log: List[Tuple[float, int, str, float]] = []
        self.reactive = reactive
        self.budget = budget
        if budget is not None and self.tel is not None:
            # mirror the budget ledger into registry metrics: every
            # charge/veto updates the spend counters and gauges below
            m = self.tel.metrics
            m.gauge("reconfig.budget_total").set(budget.total)
            m.gauge("reconfig.budget_spent").set(budget.spent)
            m.gauge("reconfig.budget_overrun").set(0.0)
            # the observer hook only mirrors charges into metrics —
            # the ledger's accept/veto decisions never read it
            # (sanctioned site, see CONTRACTS.md)
            budget.observer = self._on_budget_charge  # contract: ok TEL001

        s = self.sim
        s.on(EventKind.ROUND_START, self._on_round_start)
        s.on(EventKind.EPOCH_START, self._on_epoch_start)
        s.on(EventKind.EPOCH_END, self._on_epoch_end)
        s.on(EventKind.AGG_START, self._on_agg_start)
        s.on(EventKind.AGG_END, self._on_agg_end)
        s.on(EventKind.ROUND_END, self._on_round_end)
        s.on(EventKind.NODE_FAILURE, self._on_node_failure)
        s.on(EventKind.CAPACITY_CHANGE, self._on_capacity_change)
        s.on(EventKind.RECONFIG_END, self._on_reconfig_end)
        s.on(EventKind.STRAGGLER, self._on_straggler)
        s.on(EventKind.DEVICE_MOVE, self._on_device_move)
        s.on(EventKind.TENANT_LOAD, self._on_tenant_load)
        s.on(EventKind.FAULT_START, self._on_fault_start)
        s.on(EventKind.FAULT_END, self._on_fault_end)
        if self.tel is not None:
            # observation-only handler: DRIFT_ONSET otherwise has no
            # CoSim handler (the reactive loop registers its own).
            # Handlers never affect the trace or flush decisions, so
            # registering one conditionally preserves determinism.
            s.on(EventKind.DRIFT_ONSET, self._on_drift_telemetry)

        arr_t, arr_dev = poisson_request_arrays(
            topo.lam * cfg.rate_scale, cfg.duration_s, self.rng)
        if cfg.engine == "heap":
            for t, d in zip(arr_t, arr_dev):
                s.schedule(t, EventKind.REQUEST_ARRIVAL, node=int(d))
        else:
            self.proc.add_arrivals(arr_t, arr_dev)
        if schedule is not None:
            self.add_training(schedule)
        if reactive is not None:
            reactive.bind(self)

    # -- environment / workload injection -----------------------------------

    def add_training(self, windows: Sequence[RoundWindow]) -> int:
        """Schedule a training burst: round/epoch/aggregation events for
        every window.  Returns the schedule id (sources in the
        interference model are tagged with it, so overlapping bursts
        compose instead of clobbering each other)."""
        sid = self._sched_count
        self._sched_count += 1
        for w in windows:
            self.sim.schedule(w.start, EventKind.ROUND_START,
                              payload=(sid, w))
            self.sim.schedule(w.compute_end, EventKind.AGG_START,
                              payload=(sid, w))
            self.sim.schedule(w.upload_end, EventKind.AGG_END,
                              payload=(sid, w))
            self.sim.schedule(w.upload_end, EventKind.ROUND_END,
                              payload=(sid, w))
        return sid

    def schedule_failure(self, t: float, edge_id: int) -> None:
        self.sim.schedule(t, EventKind.NODE_FAILURE, node=edge_id)

    def schedule_capacity_change(self, t: float, edge_id: int,
                                 new_rps: float) -> None:
        self.sim.schedule(t, EventKind.CAPACITY_CHANGE, node=edge_id,
                          payload=float(new_rps))

    def schedule_drift(self, t: float, drift_mse: Optional[float] = None,
                       ) -> None:
        self.sim.schedule(t, EventKind.DRIFT_ONSET, payload=drift_mse)

    def schedule_straggler(self, t: float, device_id: int,
                           factor: float) -> None:
        """At ``t`` device ``device_id``'s not-yet-started local epochs
        take ``factor``x their nominal duration (thermal throttling, a
        co-located job, a slow link) for every round active at ``t``."""
        if factor <= 0.0:
            raise ValueError(f"straggler factor must be positive, "
                             f"got {factor}")
        self.sim.schedule(t, EventKind.STRAGGLER, node=int(device_id),
                          payload=float(factor))

    def schedule_device_move(self, t: float, device_id: int,
                             new_edge: int) -> None:
        """Device mobility: at ``t`` the device's LAN association changes
        to ``new_edge`` (its requests route there), paying a modeled
        handover — ``handover_penalty_ms`` per request for
        ``handover_s`` seconds plus ``handover_share`` demand on the
        receiving edge."""
        self.sim.schedule(t, EventKind.DEVICE_MOVE, node=int(device_id),
                          payload=int(new_edge))

    def schedule_tenant_load(self, t: float, edge_id: int, share: float,
                             duration_s: Optional[float] = None,
                             tenant: str = "t0") -> None:
        """Multi-tenant edge: a third-party job claims ``share`` of edge
        ``edge_id``'s compute from ``t`` (for ``duration_s`` seconds, or
        until a later call sets the same tenant's share to 0)."""
        src = f"tenant:{tenant}"
        self.sim.schedule(t, EventKind.TENANT_LOAD, node=int(edge_id),
                          payload=(src, float(share)))
        if duration_s is not None:
            self.sim.schedule(t + duration_s, EventKind.TENANT_LOAD,
                              node=int(edge_id), payload=(src, 0.0))

    def schedule_faults(self, plan, retry=None, standby: bool = True,
                        quorum: float = 0.0,
                        max_stale_rounds: int = 2):
        """Arm the chaos subsystem: compile ``plan`` (a
        ``repro.sim.faults.FaultPlan``) into fault windows using the
        shared per-run generator — the draws happen *here*, after the
        speed and arrival draws, so both engines see the identical
        timeline — and schedule a ``FAULT_START``/``FAULT_END`` pair
        per window.  ``retry`` is the request plane's
        :class:`~repro.sim.request_plane.RetryPolicy` (default policy
        when None); ``standby`` enables aggregator warm-standby
        promotion on crash windows; ``quorum`` > 0 enables
        partial-aggregation round credit with ``max_stale_rounds`` as
        the staleness bound.  Returns the compiled windows."""
        from repro.sim.faults import compile_plan
        from repro.sim.request_plane import RetryPolicy
        self.proc.enable_faults(retry if retry is not None
                                else RetryPolicy())
        self._faults_armed = True
        self._standby_enabled = bool(standby)
        self.quorum = float(quorum)
        self.max_stale_rounds = int(max_stale_rounds)
        wins = compile_plan(plan, self.rng,
                            n_edges=self.proc.topo.n_edges,
                            duration_s=self.cfg.duration_s)
        for k, w in enumerate(wins):
            node = w.edges[0] if w.edges else -1
            self.sim.schedule(w.t0, EventKind.FAULT_START, node=node,
                              payload=(k, w))
            self.sim.schedule(w.t1, EventKind.FAULT_END, node=node,
                              payload=(k, w))
        if self.tel is not None:
            self.tel.metrics.gauge("faults.windows_planned").set(
                float(len(wins)))
        return wins

    # -- training timeline handlers -----------------------------------------

    def _on_round_start(self, sim: Simulation, ev: Event) -> None:
        sid, w = ev.payload
        self._active_rounds += 1
        nominal = (w.compute_end - w.start) / max(w.local_epochs, 1)
        assign = self.proc.topo.assign
        participants = np.nonzero(assign >= 0)[0]
        if participants.size == 0:   # flat FL: every device trains
            participants = np.arange(len(assign))
        left = np.zeros(len(assign), dtype=int)
        per_dev: Dict[int, List[List]] = {}
        for i in participants:
            e_i = nominal * self.speed[i]
            plan = []
            for k in range(w.local_epochs):
                tok = self._tok
                self._tok += 1
                s_k = w.start + k * e_i
                sim.schedule(s_k, EventKind.EPOCH_START, node=int(i),
                             payload=(sid, w, tok))
                sim.schedule(s_k + e_i, EventKind.EPOCH_END, node=int(i),
                             payload=(sid, w, tok))
                plan.append([s_k, s_k + e_i, tok])
            per_dev[int(i)] = plan
            left[i] = w.local_epochs
        self._epochs_left[(sid, w.index)] = left
        self._epoch_sched[(sid, w.index)] = (w, per_dev)
        if self.tel is not None:
            self.tel.tracer.open(
                ("round", sid, w.index), f"round {w.index}", ev.t,
                cat="round", tid=sid, sid=sid,
                local_epochs=w.local_epochs, is_global=bool(w.is_global),
                participants=int(participants.size))
            self.tel.metrics.counter("training.rounds_started").inc()

    def _on_epoch_start(self, sim: Simulation, ev: Event) -> None:
        sid, w, tok = ev.payload
        if tok in self._cancelled:
            return                   # re-timed or dropped by a straggler
        i = ev.node
        self._busy_count[i] += 1
        self.interference.set_demand(("device", i), "epoch",
                                     self.cfg.interference.device_train_share)
        if self.tel is not None:
            # one track per device (offset past the round/agg tracks);
            # cancelled tokens returned above, so only real epochs span
            self.tel.tracer.open(("epoch", tok), f"epoch d{i}", ev.t,
                                 cat="epoch", tid=100 + i, device=i,
                                 round=w.index, sid=sid)

    def _on_epoch_end(self, sim: Simulation, ev: Event) -> None:
        sid, w, tok = ev.payload
        if tok in self._cancelled:
            return
        i = ev.node
        self._busy_count[i] -= 1
        if self.tel is not None:
            self.tel.tracer.close(("epoch", tok), ev.t)
            self.tel.metrics.counter("training.epochs_completed").inc()
        left = self._epochs_left.get((sid, w.index))
        if left is None:             # straggler epoch outlived its round
            if self._busy_count[i] == 0:
                self.interference.set_demand(("device", i), "epoch", 0.0)
            return
        left[i] -= 1
        if self._busy_count[i] == 0:
            self.interference.set_demand(("device", i), "epoch", 0.0)
            if left[i] == 0:
                # epochs done, round still open: residual work (checkpoint,
                # next-window data prep) degrades on-device serving
                self.interference.set_demand(
                    ("device", i), f"res{sid}:{w.index}",
                    self.cfg.interference.device_residual_share)

    def _on_agg_start(self, sim: Simulation, ev: Event) -> None:
        sid, w = ev.payload
        self._active_aggs.add((sid, w.index))
        share = self.cfg.interference.edge_agg_share
        for j in self.proc.edges:
            self.interference.set_demand(("edge", j), f"agg{sid}:{w.index}",
                                         share)
        if w.is_global:
            self.interference.set_demand(("cloud", 0),
                                         f"agg{sid}:{w.index}",
                                         self.cfg.interference.
                                         cloud_agg_share)
        if self.tel is not None:
            self.tel.tracer.open(("agg", sid, w.index), f"agg {w.index}",
                                 ev.t, cat="aggregation", tid=sid,
                                 sid=sid, is_global=bool(w.is_global))

    def _on_agg_end(self, sim: Simulation, ev: Event) -> None:
        sid, w = ev.payload
        self._active_aggs.discard((sid, w.index))
        src = f"agg{sid}:{w.index}"
        for j in self.proc.edges:
            self.interference.set_demand(("edge", j), src, 0.0)
        self.interference.set_demand(("cloud", 0), src, 0.0)
        if self.tel is not None:
            self.tel.tracer.close(("agg", sid, w.index), ev.t)
            self.tel.metrics.counter("training.aggs_completed").inc()

    def _on_round_end(self, sim: Simulation, ev: Event) -> None:
        sid, w = ev.payload
        self._active_rounds -= 1
        src = f"res{sid}:{w.index}"
        for i in range(len(self._busy_count)):
            self.interference.set_demand(("device", i), src, 0.0)
        self._epochs_left.pop((sid, w.index), None)
        self._epoch_sched.pop((sid, w.index), None)
        self.rounds_completed += 1
        self.last_round_end = sim.now
        # partial-aggregation quorum: a round whose upload window closed
        # with too many devices behind a down aggregator aggregates a
        # partial model — it completes, but earns no accuracy credit
        # (the reactive loop checks last_round_quorum_ok, set here
        # because CoSim's handler runs before the loop's) and counts
        # toward the staleness bound
        self.last_round_quorum_ok = True
        if self._faults_armed and self.quorum > 0.0:
            assign = self.proc.topo.assign
            down = self.proc._down
            frac_ok = 1.0
            if down and assign.size:
                bad = np.isin(assign, np.array(sorted(down),
                                               dtype=assign.dtype))
                frac_ok = 1.0 - float(np.mean(bad))
            if frac_ok < self.quorum:
                self.last_round_quorum_ok = False
                self.rounds_below_quorum += 1
                self.stale_rounds += 1
                if self.stale_rounds > self.max_stale_rounds:
                    self.stale_bound_exceeded += 1
                if self.tel is not None:
                    self.tel.metrics.counter("rounds.below_quorum").inc()
                    self.tel.metrics.gauge("rounds.stale_streak").set(
                        float(self.stale_rounds))
            else:
                self.stale_rounds = 0
        if self.tel is not None:
            self.tel.tracer.close(("round", sid, w.index), ev.t)
            self.tel.metrics.counter("training.rounds_completed").inc()

    def resolve_edge(self, edge_id: int) -> Optional[int]:
        """Current topology id of an edge named by its injection-time
        id; None when the host has been dropped since."""
        return self.edge_alias.get(int(edge_id))

    def remap_edge_alias(self, remap) -> None:
        """Compose a topology renumbering (old current id -> new
        current id, None once dead) into the injection-time alias.
        Keys are kept so a dead host stays distinguishable from an id
        that never existed."""
        self.edge_alias = {
            k: (None if v is None else remap(v))
            for k, v in self.edge_alias.items()}

    def _on_node_failure(self, sim: Simulation, ev: Event) -> None:
        cur = self.resolve_edge(ev.node)
        if cur is not None:
            self.proc.fail_edge(cur)
        if self.tel is not None:
            self.tel.tracer.instant("node_failure", ev.t, cat="fault",
                                    edge=ev.node, resolved_edge=cur)
            self.tel.metrics.counter("events.node_failure").inc()

    # -- chaos / fault-domain handlers --------------------------------------

    def _on_fault_start(self, sim: Simulation, ev: Event) -> None:
        from repro.sim.faults import DOWN_KINDS, FAULT_CRASH
        widx, w = ev.payload
        # resolve injection-time edge ids to the current topology once,
        # at window open — a mid-window recluster must not retarget it
        resolved = tuple(cur for cur in
                         (self.resolve_edge(e) for e in w.edges)
                         if cur is not None and cur in self.proc.edges)
        self._active_faults[widx] = (w.kind, w.param, resolved)
        if w.kind == FAULT_CRASH and self._standby_enabled:
            for cur in resolved:
                self._promote_standby(ev.t, widx, cur)
        self._refresh_fault_state()
        self.fault_log.append((ev.t, "start", w.kind, resolved))
        if self.tel is not None:
            self.tel.tracer.instant("fault_start", ev.t, cat="fault",
                                    kind=w.kind, edges=list(resolved),
                                    param=w.param)
            self.tel.metrics.counter("faults.windows_started").inc()
            if w.kind in DOWN_KINDS:
                self.tel.metrics.counter("faults.edges_down").inc(
                    float(len(resolved)))

    def _on_fault_end(self, sim: Simulation, ev: Event) -> None:
        widx, w = ev.payload
        entry = self._active_faults.pop(widx, None)
        if entry is None:
            return
        for failed, backup, moved in self._standby.pop(widx, []):
            # devices still parked on the standby go home; a recluster
            # in between rewrote the assignment wholesale, in which
            # case nothing matches and nothing moves
            assign = self.proc.topo.assign
            if failed in self.proc.edges:
                back = moved[assign[moved] == backup]
                assign[back] = failed
        self._refresh_fault_state()
        self.fault_log.append((ev.t, "end", w.kind, entry[2]))
        if self.tel is not None:
            self.tel.tracer.instant("fault_end", ev.t, cat="fault",
                                    kind=w.kind, edges=list(entry[2]))
            self.tel.metrics.counter("faults.windows_ended").inc()

    def _refresh_fault_state(self) -> None:
        """Recompute the request plane's fault view from the currently
        open windows — overlapping windows compose (union of down
        edges, max of drop/spike params) and closing one window never
        clears a fault another still imposes."""
        from repro.sim.faults import DOWN_KINDS, FAULT_DROP, FAULT_SPIKE
        proc = self.proc
        down: Set[int] = set()
        drop: Dict[int, float] = {}
        spike: Dict[int, float] = {}
        for widx in sorted(self._active_faults):
            kind, param, edges = self._active_faults[widx]
            for cur in edges:
                if kind in DOWN_KINDS:
                    down.add(cur)
                elif kind == FAULT_DROP:
                    drop[cur] = max(drop.get(cur, 0.0), param)
                elif kind == FAULT_SPIKE:
                    spike[cur] = max(spike.get(cur, 0.0), param)
        proc._down = down
        proc._drop_p = drop
        proc._spike_ms = spike
        proc._recompute_fault_active()

    def _promote_standby(self, t: float, widx: int, failed: int) -> None:
        """Aggregator warm-standby promotion: the crashed edge's
        devices re-associate to a healthy backup edge for the outage —
        their R1 traffic and round uploads land there — instead of
        forcing a full budget-metered recluster.  Restored at
        ``FAULT_END``; a permanent ``NODE_FAILURE`` still takes the
        recluster path."""
        from repro.sim.faults import DOWN_KINDS
        already = self._active_faults  # down set not yet refreshed
        down_now = {c for e in already.values()
                    if e[0] in DOWN_KINDS for c in e[2]}
        backups = [j for j in sorted(self.proc.edges)
                   if j != failed and j not in down_now]
        if not backups:
            return
        backup = backups[0]
        assign = self.proc.topo.assign
        moved = np.flatnonzero(assign == failed)
        if moved.size == 0:
            return
        assign[moved] = backup
        self._standby.setdefault(widx, []).append(
            (failed, backup, moved))
        self.standby_promotions += 1
        if self.tel is not None:
            self.tel.tracer.instant("standby_promotion", t, cat="fault",
                                    failed_edge=failed, backup=backup,
                                    devices=int(moved.size))
            self.tel.metrics.counter("faults.standby_promotions").inc()

    def _on_capacity_change(self, sim: Simulation, ev: Event) -> None:
        """Apply the new rate to the edge's admission state even without
        a reactive loop (which would additionally re-cluster): the edge
        host genuinely got slower/faster, reactions or not."""
        cur = self.resolve_edge(ev.node)
        st = self.proc.edges.get(cur) if cur is not None else None
        if st is not None:
            st.capacity_rps = float(ev.payload)
            st.tokens = min(st.tokens, st.capacity_rps * st.burst_s)
        if self.tel is not None:
            self.tel.tracer.instant("capacity_change", ev.t, cat="fault",
                                    edge=ev.node,
                                    new_rps=float(ev.payload))
            self.tel.metrics.counter("events.capacity_change").inc()

    # -- scenario events: stragglers, mobility, multi-tenant edges ----------

    def _on_straggler(self, sim: Simulation, ev: Event) -> None:
        """Re-time the device's not-yet-started epochs in every active
        round: each takes ``factor``x its planned duration and they run
        back-to-back from the straggle onset (or from the end of the
        epoch currently in flight).  A reactive loop registered after
        this handler reads :meth:`straggler_info` for the projected
        finish times and applies its deadline-based drop policy."""
        i, factor, t = int(ev.node), float(ev.payload), ev.t
        info: List[Tuple[int, RoundWindow, float]] = []
        for (sid, widx), (w, per_dev) in self._epoch_sched.items():
            plan = per_dev.get(i)
            if not plan:
                continue
            kept = [e for e in plan if e[0] <= t]
            pending = [e for e in plan if e[0] > t]
            if not pending:
                continue             # nothing left to slow this round
            resume = max(t, kept[-1][1]) if kept else t
            for start, end, tok in pending:
                self._cancelled.add(tok)
                dur = (end - start) * factor
                new_tok = self._tok
                self._tok += 1
                sim.schedule(resume, EventKind.EPOCH_START, node=i,
                             payload=(sid, w, new_tok))
                sim.schedule(resume + dur, EventKind.EPOCH_END, node=i,
                             payload=(sid, w, new_tok))
                kept.append([resume, resume + dur, new_tok])
                resume += dur
            per_dev[i] = kept
            info.append((sid, w, kept[-1][1]))
        self._straggler_info[i] = info
        if self.tel is not None:
            self.tel.tracer.instant("straggler", t, cat="fault",
                                    device=i, factor=factor,
                                    rounds_affected=len(info))
            self.tel.metrics.counter("events.straggler").inc()

    def straggler_info(self, device_id: int,
                       ) -> List[Tuple[int, RoundWindow, float]]:
        """(schedule id, round window, projected epoch-finish time) per
        round the last STRAGGLER event on ``device_id`` touched."""
        return list(self._straggler_info.get(int(device_id), []))

    def drop_from_round(self, device_id: int, sid: int, round_index: int,
                        ) -> int:
        """Deadline-based partial aggregation: cancel the device's
        not-yet-started epochs in one round (the epoch in flight, if
        any, finishes and is wasted work).  Returns the number of epochs
        dropped."""
        entry = self._epoch_sched.get((sid, round_index))
        if entry is None:
            return 0
        _, per_dev = entry
        now = self.sim.now
        dropped, kept = 0, []
        for start, end, tok in per_dev.get(int(device_id), []):
            if start > now and tok not in self._cancelled:
                self._cancelled.add(tok)
                dropped += 1
            else:
                kept.append([start, end, tok])
        per_dev[int(device_id)] = kept
        if dropped:
            self.drop_log.append((now, int(device_id), int(round_index),
                                  dropped))
        return dropped

    def _on_device_move(self, sim: Simulation, ev: Event) -> None:
        """Mobility handover: re-home the device's requests on the new
        LAN edge and pay the modeled handover cost.  A reactive loop
        additionally updates the controller inventory (and may
        re-cluster, budget permitting).  The target edge is named by
        its injection-time id; if that host has been dropped since, the
        handover is abandoned (the device stays where it is)."""
        i, j_raw, t = int(ev.node), int(ev.payload), ev.t
        assign = self.proc.topo.assign
        if not (0 <= i < len(assign)):
            return
        if j_raw >= 0 and j_raw not in self.edge_alias:
            raise ValueError(f"device {i} moved to unknown edge {j_raw} "
                             f"(never part of the topology)")
        j_new = self.resolve_edge(j_raw) if j_raw >= 0 else j_raw
        if j_new is None:
            return                   # target host died before the handover
        j_old = int(assign[i])
        assign[i] = j_new
        if j_new >= 0 and j_new not in self.proc.edges:
            # the target edge had no cluster yet: open admission state
            # with its physical capacity
            r = self.proc.topo.r
            self.proc.edges[j_new] = EdgeState(
                capacity_rps=float(r[j_new]) if r.size else np.inf)
        # a device has at most one handover in flight: a new move
        # supersedes the previous one's edge load everywhere
        src = f"handover:{i}"
        self.interference.clear_tier("edge", source=src)
        self._handover_until[i] = t + self.cfg.handover_s
        if j_new >= 0:
            self.interference.set_demand(
                ("edge", j_new), src, self.cfg.interference.handover_share)
            sim.schedule(t + self.cfg.handover_s, EventKind.TENANT_LOAD,
                         node=j_raw, payload=(src, 0.0))
        self.move_log.append((t, i, j_old, j_new))
        if self.tel is not None:
            self.tel.tracer.instant("device_move", t, cat="mobility",
                                    device=i, old_edge=j_old,
                                    new_edge=j_new)
            self.tel.metrics.counter("events.device_move").inc()

    def _on_tenant_load(self, sim: Simulation, ev: Event) -> None:
        """External edge demand change: a third-party tenant job starts
        (share > 0) or ends (share == 0) on the edge — also reused to
        clear handover load.  Edge named by injection-time id (dropped
        hosts swallow their jobs); a handover clear is skipped when a
        newer handover of the same device extended the window."""
        src, share = ev.payload
        src = str(src)
        if src.startswith("handover:") and share == 0.0:
            dev = int(src.split(":", 1)[1])
            if ev.t < self._handover_until[dev] - 1e-9:
                return               # superseded by a newer handover
        j = self.resolve_edge(ev.node)
        if j is None:
            return
        self.interference.set_demand(("edge", j), src, float(share))
        self.tenant_log.append((ev.t, j, src, float(share)))
        if self.tel is not None:
            self.tel.metrics.counter("events.tenant_load").inc()

    def _on_drift_telemetry(self, sim: Simulation, ev: Event) -> None:
        self.tel.tracer.instant("drift_onset", ev.t, cat="fault",
                                drift_mse=ev.payload)
        self.tel.metrics.counter("events.drift_onset").inc()

    def _on_budget_charge(self, entry: BudgetEntry) -> None:
        """ReconfigBudget observer: mirror every ledger entry into the
        registry (spend/deferral counters + running budget gauges) so
        grid cells report budget accounting as metrics, not only as
        scenario-result fields."""
        m = self.tel.metrics
        m.counter("reconfig.attempts").inc()
        if entry.applied:
            m.counter("reconfig.applied").inc()
            m.counter("reconfig.cost_spent").inc(entry.cost)
        else:
            m.counter("reconfig.deferred").inc()
        if entry.forced:
            m.counter("reconfig.forced").inc()
        b = self.budget
        m.gauge("reconfig.budget_spent").set(b.spent)
        m.gauge("reconfig.budget_remaining").set(b.remaining)
        m.gauge("reconfig.budget_overrun").set(max(b.spent - b.total, 0.0))

    # -- reactive-deployment plumbing ---------------------------------------

    def reconfig_cost(self, deployment=None,
                      n_edges: Optional[int] = None) -> float:
        """Modeled cost of one deployment swap, in edge-compute-seconds:
        every open edge of the incoming topology carries
        ``migration_share`` demand for ``reconfig_s`` seconds.  Pass
        ``n_edges`` to bound the cost *before* solving (the reactive
        loop pre-checks the budget against the inventory size — an
        upper bound on open edges — so a swap is never vetoed after the
        controller has already been mutated)."""
        if n_edges is None:
            topo = deployment.topology if deployment is not None else \
                self.proc.topo
            n_edges = len(topo.open_edges)
        return (self.cfg.reconfig_s
                * self.cfg.interference.migration_share * max(n_edges, 1))

    def apply_deployment(self, deployment, reason: str = "recluster",
                         forced: bool = False,
                         absorb: bool = False) -> bool:
        """Swap in a re-clustered deployment mid-simulation, paying a
        modeled reconfiguration cost: replicas migrate for
        ``reconfig_s`` seconds during which edges carry migration load
        and every edge-touching request pays ``reconfig_penalty_ms``.

        When a :class:`ReconfigBudget` is attached, the swap is metered
        first — an unaffordable, non-``forced`` swap is vetoed (returns
        False, the deployment does NOT go live).  ``absorb=True`` folds
        the swap into a migration window that is still open (a failure
        recluster superseding an in-flight swap): the budget is *not*
        charged again — the running migration already paid — the
        migration clock just restarts on the new target.

        With telemetry attached, every attempt lands in the decision
        audit log: trigger (the ``reason`` string the reactive loop
        passes), modeled migration cost, whether the budget was
        charged, and applied / forced (overrun) / absorbed / vetoed
        outcome."""
        t = self.sim.now
        cost = self.reconfig_cost(deployment)
        if absorb:
            cost = 0.0               # in-flight window already paid
        affordable = self.budget is None or self.budget.can_afford(cost)
        if self.budget is not None and not absorb and not self.budget.charge(
                t, cost, reason, forced=forced):
            if self.tel is not None:
                self.tel.audit.record(
                    t, "deployment_swap", trigger=reason,
                    outcome="vetoed", cost=cost, charged=False,
                    evidence={"budget_remaining": self.budget.remaining,
                              "budget_total": self.budget.total})
            return False
        self.proc.set_topology(deployment.topology)
        # training demands were keyed by old edge ids: rebuild the edge
        # tier (external tenant/handover load stays — a third-party job
        # doesn't vanish because HFL re-clustered)
        self.interference.clear_tier(
            "edge", keep_prefixes=EXTERNAL_DEMAND_PREFIXES)
        share = self.cfg.interference.edge_agg_share
        for sid, idx in self._active_aggs:
            for j in self.proc.edges:
                self.interference.set_demand(("edge", j),
                                             f"agg{sid}:{idx}", share)
        for j in self.proc.edges:
            self.interference.set_demand(
                ("edge", j), "migration",
                self.cfg.interference.migration_share)
        self.reconfig_until = t + self.cfg.reconfig_s
        self.reconfig_times.append(t)
        self.sim.schedule(self.reconfig_until, EventKind.RECONFIG_END)
        if self.tel is not None:
            evidence = {"n_edges": len(self.proc.topo.open_edges)}
            if self.budget is not None:
                evidence["budget_remaining"] = self.budget.remaining
            self.tel.audit.record(
                t, "deployment_swap", trigger=reason,
                outcome=("absorbed" if absorb
                         else "applied" if affordable else "forced"),
                cost=cost, charged=self.budget is not None and not absorb,
                forced=forced, evidence=evidence)
            # migration window has a known duration — record it whole
            self.tel.tracer.complete(
                "deployment swap", t, self.cfg.reconfig_s,
                cat="reconfig", tid=50, trigger=reason, cost=cost)
            self.tel.metrics.counter("reconfig.swaps").inc()
        return True

    def _on_reconfig_end(self, sim: Simulation, ev: Event) -> None:
        if sim.now >= self.reconfig_until:
            self.interference.clear_tier("edge", "migration")

    # -- pluggable policies for the request processor -----------------------

    def _flush_gate(self, ev: Event) -> Optional[bool]:
        """Dynamic refinement of the static window-fusion table
        (``events.EVENT_EFFECTS``): an epoch boundary only mutates
        routing inputs when it actually flips the device's busy flag.
        A cancelled (straggler-re-timed / deadline-dropped) epoch's
        events are no-ops outright; an ``EPOCH_START`` on an
        already-busy device, or an ``EPOCH_END`` that leaves other
        epochs in flight (overlapping training bursts), changes neither
        the busy mask nor the device's ``epoch`` interference demand —
        those windows fuse.  Decided strictly from state the handlers
        have not yet touched."""
        k = ev.kind
        if k is EventKind.EPOCH_START or k is EventKind.EPOCH_END:
            tok = ev.payload[2]
            if tok in self._cancelled:
                return False
            busy = self._busy_count[ev.node]
            return busy == 0 if k is EventKind.EPOCH_START else busy <= 1
        return None

    @property
    def training_active(self) -> bool:
        return self._active_rounds > 0

    def _busy(self, i: int, t: float) -> bool:
        return self._busy_count[i] > 0

    def _busy_mask(self, devices: np.ndarray, ts: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`_busy` for the batched request plane (the
        busy counts change only at control events, so one lookup at
        flush time covers the whole window)."""
        return self._busy_count[devices] > 0

    def _request_penalty(self, dec: RouteDecision, t: float,
                         device: int) -> float:
        extra = 0.0
        if t < self.reconfig_until and dec.edge is not None:
            extra += self.cfg.reconfig_penalty_ms
        # handover churn hits the network path, not on-device serving
        if t < self._handover_until[device] and dec.tier != "device":
            extra += self.cfg.handover_penalty_ms
        return extra

    def _request_penalty_vec(self, ts: np.ndarray, devices: np.ndarray,
                             tiers: np.ndarray, edge_ids: np.ndarray,
                             ) -> np.ndarray:
        """Vectorized :meth:`_request_penalty`: ``edge_ids >= 0`` marks
        requests whose route touched an edge (R1 admission or R3
        forwarding), ``tiers`` uses the request-plane TIER codes."""
        extra = np.zeros(ts.size)
        extra[(edge_ids >= 0) & (ts < self.reconfig_until)] += \
            self.cfg.reconfig_penalty_ms
        extra[(tiers != TIER_DEVICE)
              & (ts < self._handover_until[devices])] += \
            self.cfg.handover_penalty_ms
        return extra

    # -- run ----------------------------------------------------------------

    def run(self) -> CoSimResult:
        self.sim.run(until=self.cfg.duration_s)
        if self.tel is not None:
            m = self.tel.metrics
            m.gauge("sim.duration_s").set(self.sim.now)
            m.gauge("sim.fused_windows").set(self.sim.fused_windows)
            m.gauge("sim.rounds_completed").set(self.rounds_completed)
        mse = (np.asarray(self.reactive.mse_series)
               if self.reactive is not None and self.reactive.mse_series
               else np.zeros((0, 2)))
        actions = (list(self.reactive.actions)
                   if self.reactive is not None else [])
        fault_stats: Dict[str, int] = {}
        if self._faults_armed:
            p = self.proc
            fault_stats = {
                "fault_attempts": p.fault_attempts,
                "fault_drops": p.fault_drops,
                "retries_scheduled": p.retries_scheduled,
                "retries_dispatched": p.retries_dispatched,
                "retries_pending": (p.retries_scheduled
                                    - p.retries_dispatched),
                "failovers": p.failovers,
                "standby_promotions": self.standby_promotions,
                "rounds_below_quorum": self.rounds_below_quorum,
                "stale_bound_exceeded": self.stale_bound_exceeded,
            }
        return CoSimResult(log=self.proc.log(), trace=list(self.sim.trace),
                           rounds_completed=self.rounds_completed,
                           reconfig_times=list(self.reconfig_times),
                           mse_series=mse, actions=actions,
                           budget=self.budget,
                           drop_log=list(self.drop_log),
                           move_log=list(self.move_log),
                           fault_stats=fault_stats)
