"""Roofline analysis from compiled dry-run artifacts (no real hardware):

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = per-device collective operand bytes / link_bw
               (pod-axis traffic is charged at DCI bandwidth)

``cost_analysis()`` of an SPMD-partitioned module reports the per-device
program, so all three terms are per-chip seconds directly comparable to
one another — the dominant term approximates step wall time."""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.launch.mesh import (DCI_BW, HBM_BW, ICI_BW_PER_LINK,
                               PEAK_FLOPS_BF16)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_IOTA_GROUPS_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_IOTA_SIMPLE_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _first_group_ids(line: str):
    """Reconstruct the device ids of the first replica group (iota or
    explicit-list format).  Returns (ids, group_size) or (None, 1)."""
    import numpy as np
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        G, N = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            ids = ids.transpose(perm)
        groups = ids.reshape(G, N)
        return groups, N
    m2 = _IOTA_SIMPLE_RE.search(line)
    if m2:
        G, N = int(m2.group(1)), int(m2.group(2))
        return np.arange(N)[None, :], N
    m3 = _LIST_GROUPS_RE.search(line)
    if m3:
        ids = np.asarray([int(x) for x in m3.group(1).split(",") if x])
        return ids[None, :], ids.size
    return None, 1


def _crosses_pod(line: str, pod_size: int) -> bool:
    """True if ANY replica group spans more than one pod."""
    import numpy as np
    groups, _ = _first_group_ids(line)
    if groups is None:
        return False
    pods = np.asarray(groups) // pod_size
    return bool(np.any(pods.min(axis=1) != pods.max(axis=1)))


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)
    cross_pod_bytes: float = 0.0      # traffic whose groups span pods

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str, pod_size: Optional[int] = None
                     ) -> CollectiveStats:
    """Sum per-instruction operand bytes for every collective op.

    ``pod_size``: when given (e.g. 256 on the 2x16x16 mesh), each
    instruction's replica groups are reconstructed (iota and explicit-list
    formats) and classified as cross-pod if any group spans devices from
    more than one pod."""
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("//") or " = " not in s:
            continue
        rhs = s.split(" = ", 1)[1]
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start|-done)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        if re.search(r"\ball-reduce-done\(|\ball-gather-done\(", rhs):
            continue  # bytes counted at -start
        # result shapes = everything before the opcode token
        op_pos = rhs.find(kind)
        result_part = rhs[:op_pos]
        operand_part = rhs[op_pos:]
        res_shapes = _SHAPE_RE.findall(result_part)
        res_bytes = sum(_shape_bytes(d, dims) for d, dims in res_shapes)
        _, gsize = _first_group_ids(s) if "replica_groups" in s else (None, 1)
        if kind == "all-gather":
            op_bytes = res_bytes / max(gsize, 1)
        elif kind == "reduce-scatter":
            op_bytes = res_bytes * max(gsize, 1)
        else:
            op_bytes = res_bytes
        st.bytes_by_kind[kind] = st.bytes_by_kind.get(kind, 0.0) + op_bytes
        st.count_by_kind[kind] = st.count_by_kind.get(kind, 0) + 1
        if pod_size and _crosses_pod(s, pod_size):
            st.cross_pod_bytes += op_bytes
    return st


@dataclass
class Roofline:
    flops: float                      # per-device HLO flops
    bytes_accessed: float             # per-device HLO bytes
    collectives: CollectiveStats
    n_chips: int
    model_flops: float = 0.0          # 6*N*D (or 6*N_active*D) global

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        intra = self.collectives.total_bytes - self.collectives.cross_pod_bytes
        return (intra / ICI_BW_PER_LINK
                + self.collectives.cross_pod_bytes / DCI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (per-device flops * chips): remat/redundancy."""
        total = self.flops * self.n_chips
        return self.model_flops / total if total else 0.0

    def as_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "collective_bytes_per_device": self.collectives.total_bytes,
            "collective_bytes_by_kind": self.collectives.bytes_by_kind,
            "collective_counts": self.collectives.count_by_kind,
            "cross_pod_bytes": self.collectives.cross_pod_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def analyze(compiled, mesh, model_flops: float = 0.0,
            multi_pod: bool = False) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    n_chips = mesh.devices.size
    pod_size = 256 if multi_pod else None
    st = collective_stats(compiled.as_text(), pod_size)
    return Roofline(flops=flops, bytes_accessed=bytes_accessed,
                    collectives=st, n_chips=n_chips,
                    model_flops=model_flops)


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6 * N(active) * D  (train);  2 * N * D_new (decode);
    2 * N * D (prefill)."""
    n_active = cfg.model.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
