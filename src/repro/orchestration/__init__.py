from repro.orchestration.controller import Deployment, LearningController
from repro.orchestration.gpo import (DeviceNode, EdgeNode, Inventory,
                                     random_inventory)

__all__ = ["Deployment", "LearningController", "DeviceNode", "EdgeNode",
           "Inventory", "random_inventory"]
