"""The paper's primary contribution: HFLOP (inference-aware hierarchical
FL orchestration) — problem model, exact + heuristic solvers,
communication-cost accounting, and the cluster topology object consumed
by the FL runtime, the inference router, and the TPU mesh mapping."""
from repro.core.hflop import (HFLOPInstance, HFLOPSolution, build_ilp,
                              is_feasible, objective, paper_cost_instance,
                              random_instance, violations)
from repro.core.solvers import (local_search, solve_bnb, solve_bruteforce,
                                solve_decomposed, solve_greedy,
                                solve_heuristic, solve_uncapacitated)
from repro.core.partition import (LanHFLOPInstance, Partition,
                                  default_regions, paper_cost_lan,
                                  partition_instance, sub_instance)
from repro.core.costmodel import (GRU_MODEL_BYTES, CostReport, flat_fl_cost,
                                  hfl_cost, savings_vs_flat)
from repro.core.topology import ClusterTopology

__all__ = [
    "HFLOPInstance", "HFLOPSolution", "build_ilp", "is_feasible",
    "objective", "paper_cost_instance", "random_instance", "violations",
    "local_search", "solve_bnb", "solve_bruteforce", "solve_decomposed",
    "solve_greedy", "solve_heuristic", "solve_uncapacitated",
    "LanHFLOPInstance", "Partition", "default_regions", "paper_cost_lan",
    "partition_instance", "sub_instance", "GRU_MODEL_BYTES",
    "CostReport", "flat_fl_cost", "hfl_cost", "savings_vs_flat",
    "ClusterTopology",
]
