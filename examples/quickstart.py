"""Quickstart: the paper's pipeline in ~60 lines.

  1. describe the infrastructure (devices with inference rates, edge
     hosts with serving capacities)
  2. solve HFLOP -> inference-load-aware cluster topology
  3. train continually (hierarchical FedAvg) on traffic data
  4. serve inference requests with R1-R3 routing, compare latencies
  5. account communication costs vs flat FL

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import get_config
from repro.core import flat_fl_cost, hfl_cost
from repro.data.traffic import generate, select_fl_sensors
from repro.fl.hierarchy import ContinualHFL, HFLRunConfig
from repro.orchestration import DeviceNode, EdgeNode, Inventory, \
    LearningController
from repro.routing import SimConfig, compare_methods

# 1. infrastructure ---------------------------------------------------------
ds = generate(num_days=30, seed=0)
sensors = select_fl_sensors(ds, per_cluster=2, seed=0)     # 8 FL clients
rng = np.random.default_rng(0)
lam = rng.uniform(2.0, 6.0, len(sensors))                  # req/s per device
devices = [DeviceNode(i, lam=float(lam[i]),
                      lan_edge=int(ds.cluster_of[sensors[i]]))
           for i in range(len(sensors))]
edges = [EdgeNode(j, capacity_rps=float(lam.sum() / 4 * 1.4))
         for j in range(4)]

# 2. inference-aware clustering (HFLOP, paper §IV) --------------------------
controller = LearningController(Inventory(devices, edges), l=2)
deployment = controller.deploy()
print(deployment.topology.describe())

# 3. continual hierarchical FL (paper §V-B) ---------------------------------
cfg = get_config("gru-traffic")
run = HFLRunConfig(rounds=3, max_batches=15, max_val_windows=128)
hfl = ContinualHFL(cfg, ds, sensors, deployment.topology, run, mode="hier")
result = hfl.run_rounds(progress=True)
print(f"val MSE: round0={result.mse.mean(1)[0]:.4f} -> "
      f"round{len(result.mse) - 1}={result.mse.mean(1)[-1]:.4f}")

# 4. inference serving with R1-R3 routing (paper §V-C) ----------------------
inst = controller.inventory.to_instance(l=2)
logs = compare_methods(inst, {"flat": None,
                              "hflop": deployment.topology.assign},
                       SimConfig(duration_s=60, seed=0))
for name, log in logs.items():
    print(f"latency[{name}] = {log.mean_latency():.2f} "
          f"+- {log.std_latency():.2f} ms  "
          f"(cloud fraction {log.tier_fractions()['cloud']:.2f})")

# 5. communication-cost accounting (paper §V-D) -----------------------------
flat = flat_fl_cost(inst.n, total_rounds=100)
hier = hfl_cost(inst, deployment.topology.assign, total_rounds=100)
print(f"comm volume to convergence: flat={flat.gigabytes:.2f} GB, "
      f"HFLOP={hier.gigabytes:.2f} GB "
      f"({100 * (1 - hier.metered_bytes / flat.metered_bytes):.0f}% saved)")
