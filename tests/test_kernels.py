"""Pallas kernel validation: shape/dtype sweeps, assert_allclose against
the pure-jnp oracles in kernels/ref.py (interpret=True on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels import ops, ref

R = np.random.default_rng(0)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(R.normal(size=shape) * scale, dtype)


TOL = {jnp.float32: dict(atol=3e-5, rtol=3e-5),
       jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


@pytest.mark.parametrize("T,D,bq,bk", [(128, 64, 64, 64), (256, 32, 64, 128),
                                       (256, 128, 128, 64)])
@pytest.mark.parametrize("window", [0, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(T, D, bq, bk, window, dtype):
    q, k, v = (_arr((2, T, D), dtype) for _ in range(3))
    o = ops.flash_attention(q, k, v, causal=True, window=window,
                            bq=bq, bk=bk)
    r = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    assert o.dtype == q.dtype
    assert_allclose(np.asarray(o, np.float32), np.asarray(r, np.float32),
                    **TOL[dtype])


@pytest.mark.parametrize("H,Hkv,C,bk", [(8, 2, 256, 64), (4, 4, 128, 128),
                                        (16, 2, 512, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(H, Hkv, C, bk, dtype):
    B, D = 2, 64
    q = _arr((B, H, D), dtype)
    k = _arr((B, C, Hkv, D), dtype)
    v = _arr((B, C, Hkv, D), dtype)
    valid = jnp.asarray(R.uniform(size=(B, C)) < 0.8)
    valid = valid.at[:, 0].set(True)     # at least one valid slot
    o = ops.decode_attention(q, k, v, valid, bk=bk)
    r = ref.decode_attention_ref(q, k, v, valid)
    assert_allclose(np.asarray(o, np.float32), np.asarray(r, np.float32),
                    **TOL[dtype])


@pytest.mark.parametrize("B,T,h,bb", [(8, 12, 32, 4), (4, 24, 64, 4),
                                      (2, 8, 128, 2)])
def test_gru_seq_sweep(B, T, h, bb):
    xw = _arr((B, T, 3 * h))
    h0 = _arr((B, h))
    wh = _arr((h, 3 * h), scale=0.1)
    o = ops.gru_seq(xw, h0, wh, bb=bb)
    r = ref.gru_seq_ref(xw, h0, wh)
    assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("C,N,bn", [(20, 1000, 256), (4, 513, 128),
                                    (32, 4096, 4096)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedavg_reduce_sweep(C, N, bn, dtype):
    x = _arr((C, N), dtype)
    w = jnp.asarray(R.uniform(0.5, 2.0, C), jnp.float32)
    o = ops.fedavg_reduce(x, w, bn=bn)
    r = ref.fedavg_reduce_ref(x, w)
    assert_allclose(np.asarray(o, np.float32), np.asarray(r, np.float32),
                    **TOL[dtype])


@pytest.mark.parametrize("T,E,k,bt", [(64, 16, 4, 32), (128, 60, 4, 64),
                                      (32, 64, 6, 32)])
def test_topk_router_sweep(T, E, k, bt):
    logits = _arr((T, E))
    w1, i1 = ops.topk_router(logits, k, bt=bt)
    w2, i2 = ref.topk_router_ref(logits, k)
    assert_allclose(np.asarray(w1), np.asarray(w2), atol=1e-6)
    assert (np.asarray(i1) == np.asarray(i2)).all()


@pytest.mark.parametrize("L,H,P,N,chunk", [(128, 4, 16, 8, 32),
                                           (64, 2, 32, 16, 64),
                                           (96, 8, 8, 8, 32)])
def test_mamba_chunk_scan_sweep(L, H, P, N, chunk):
    B = 2
    x = _arr((B, L, H, P))
    dt = jnp.asarray(R.uniform(0.01, 0.2, (B, L, H)), jnp.float32)
    A = jnp.asarray(-R.uniform(0.5, 2.0, H), jnp.float32)
    Bm = _arr((B, L, N))
    Cm = _arr((B, L, N))
    y, s = ops.mamba_chunk_scan(x, dt, A, Bm, Cm, chunk=chunk)
    yr, sr = ref.mamba_chunk_ref(x, dt, A, Bm[:, :, None, :],
                                 Cm[:, :, None, :], chunk)
    assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-4, rtol=5e-4)
    assert_allclose(np.asarray(s), np.asarray(sr), atol=5e-4, rtol=5e-4)


def test_mamba_head_blocking_equivalence():
    """bh < H must give identical results (VMEM tiling invariance)."""
    B, L, H, P, N = 1, 64, 4, 8, 8
    x = _arr((B, L, H, P))
    dt = jnp.asarray(R.uniform(0.01, 0.2, (B, L, H)), jnp.float32)
    A = jnp.asarray(-R.uniform(0.5, 2.0, H), jnp.float32)
    Bm, Cm = _arr((B, L, N)), _arr((B, L, N))
    y1, s1 = ops.mamba_chunk_scan(x, dt, A, Bm, Cm, chunk=32, bh=4)
    y2, s2 = ops.mamba_chunk_scan(x, dt, A, Bm, Cm, chunk=32, bh=2)
    assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5, rtol=1e-5)
    assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# paged decode attention (block-table gather through scalar prefetch)
# ---------------------------------------------------------------------------

def _block_tables(B, Pseq, num_pages):
    """Distinct page ids per (seq, page) slot — a permutation, so the
    kernel's gather is exercised on genuinely scattered pages."""
    ids = R.permutation(num_pages)[:B * Pseq].reshape(B, Pseq)
    return jnp.asarray(ids, jnp.int32)


@pytest.mark.parametrize("H,Hkv,ps,Pseq", [(8, 2, 16, 4), (4, 4, 8, 6)])
@pytest.mark.parametrize("soft_cap,window", [(0.0, None), (30.0, None),
                                             (0.0, 20)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_sweep(H, Hkv, ps, Pseq, soft_cap, window,
                                      dtype):
    B, D = 2, 64
    num_pages = B * Pseq + 3
    q = _arr((B, H, D), dtype)
    k_pages = _arr((num_pages, ps, Hkv, D), dtype)
    v_pages = _arr((num_pages, ps, Hkv, D), dtype)
    bt = _block_tables(B, Pseq, num_pages)
    lengths = jnp.asarray(R.integers(1, Pseq * ps + 1, (B,)), jnp.int32)
    o = ops.paged_decode_attention(q, k_pages, v_pages, bt, lengths,
                                   soft_cap=soft_cap, window=window)
    r = ref.paged_decode_attention_ref(q, k_pages, v_pages, bt, lengths,
                                       soft_cap=soft_cap, window=window)
    assert o.dtype == q.dtype
    assert_allclose(np.asarray(o, np.float32), np.asarray(r, np.float32),
                    **TOL[dtype])


@pytest.mark.parametrize("H,R_dim,Dr,ps,Pseq", [(8, 64, 16, 16, 4),
                                                (4, 128, 32, 8, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_mla_decode_attention_sweep(H, R_dim, Dr, ps, Pseq, dtype):
    B = 2
    num_pages = B * Pseq + 2
    q_c = _arr((B, H, R_dim), dtype)
    q_rope = _arr((B, H, Dr), dtype)
    ckv_pages = _arr((num_pages, ps, R_dim), dtype)
    krope_pages = _arr((num_pages, ps, Dr), dtype)
    bt = _block_tables(B, Pseq, num_pages)
    lengths = jnp.asarray(R.integers(1, Pseq * ps + 1, (B,)), jnp.int32)
    scale = 1.0 / np.sqrt(R_dim + Dr)
    o = ops.paged_mla_decode_attention(q_c, q_rope, ckv_pages, krope_pages,
                                       bt, lengths, scale=scale)
    r = ref.paged_mla_decode_attention_ref(q_c, q_rope, ckv_pages,
                                           krope_pages, bt, lengths,
                                           scale=scale)
    assert o.dtype == q_c.dtype
    assert_allclose(np.asarray(o, np.float32), np.asarray(r, np.float32),
                    **TOL[dtype])


def test_paged_decode_attention_matches_dense_gather():
    """Paged layout is an addressing change only: gathering the pages
    back into a contiguous cache and calling the dense decode oracle
    must agree with the paged kernel."""
    B, H, Hkv, D, ps, Pseq = 2, 8, 2, 64, 8, 4
    num_pages = B * Pseq + 1
    q = _arr((B, H, D))
    k_pages = _arr((num_pages, ps, Hkv, D))
    v_pages = _arr((num_pages, ps, Hkv, D))
    bt = _block_tables(B, Pseq, num_pages)
    lengths = jnp.asarray([Pseq * ps, 11], jnp.int32)
    o = ops.paged_decode_attention(q, k_pages, v_pages, bt, lengths)
    k = k_pages[bt].reshape(B, Pseq * ps, Hkv, D)
    v = v_pages[bt].reshape(B, Pseq * ps, Hkv, D)
    valid = jnp.arange(Pseq * ps)[None, :] < lengths[:, None]
    r = ref.decode_attention_ref(q, k, v, valid)
    assert_allclose(np.asarray(o), np.asarray(r), atol=3e-5, rtol=3e-5)
