"""Fixture: lazy serving facade (PEP 562)."""
import importlib

_LAZY = {"Engine": "repro.serving.engine"}


def __getattr__(name):
    module = _LAZY.get(name)
    if module is None:
        raise AttributeError(name)
    return getattr(importlib.import_module(module), name)
