"""Serving driver: continuous-batching scheduler over a Poisson inference
workload — the TPU-side realization of the paper's inference path.

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m \
      --requests 32 --slots 8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import make_model
from repro.routing import LatencyModel
from repro.serving import (ContinuousBatchingScheduler, ServeEngine,
                           poisson_requests, requests_from_events)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--slots", type=int, default=8,
                    help="continuous-batching slots (concurrency cap)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--rate", type=float, default=20.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    api = make_model(cfg)
    params, _ = api.init_params(jax.random.key(0))
    engine = ServeEngine(cfg, params, batch_size=args.slots, max_len=256)

    lam = np.full(args.slots, args.rate / args.slots)
    events = poisson_requests(lam, duration_s=args.requests / args.rate,
                              seed=0)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, max(cfg.model.vocab_size, 2),
                           (len(events), args.prompt_len))
    reqs = requests_from_events(events, prompts,
                                max_new_tokens=args.decode_steps)
    print(f"{len(events)} requests over {args.requests / args.rate:.1f}s "
          f"({args.slots} slots)")

    # warm the compile caches so TTFT reflects serving, not tracing
    meas = engine.measure(prompt_len=args.prompt_len,
                          decode_steps=args.decode_steps)
    print(f"engine: prefill {meas.prefill_ms:.1f}ms, "
          f"decode {meas.decode_ms_per_token:.2f}ms/token "
          f"@ {meas.batch_size} slots")

    sched = ContinuousBatchingScheduler(engine)
    stats = sched.run(reqs)
    print(f"served {len(sched.completed)} requests: {stats.summary()}")

    lat = LatencyModel.from_measurements(
        {"edge": meas}, decode_tokens=args.decode_steps)
    print(f"calibrated edge service time: "
          f"{lat.infer_ms('edge'):.2f}ms/request "
          f"(x{lat.infer_ms('edge', occupancy=2 * args.slots) / max(lat.infer_ms('edge'), 1e-9):.1f} "
          f"at 2x oversubscription)")


if __name__ == "__main__":
    main()
