"""Optimizers as pure pytree transforms (no optax in the container).

AdamW with configurable state dtype (llama3-405b runs bf16 moments to fit
HBM — DESIGN.md §5) and SGD+momentum for FL client steps (the paper's
clients run plain gradient descent locally)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array
    m: PyTree
    v: PyTree


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    state_dtype: str = "float32"
    warmup_steps: int = 100

    def _sched(self, step):
        warm = jnp.minimum(1.0, (step + 1) / self.warmup_steps)
        return self.lr * warm

    def init(self, params: PyTree) -> AdamWState:
        dt = jnp.dtype(self.state_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def update(self, grads: PyTree, state: AdamWState, params: PyTree
               ) -> Tuple[PyTree, AdamWState]:
        step = state.step + 1
        lr = self._sched(step)
        b1, b2 = self.b1, self.b2
        dt = jnp.dtype(self.state_dtype)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v2 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mhat = m2 / (1 - b1 ** step)
            vhat = v2 / (1 - b2 ** step)
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            p2 = p.astype(jnp.float32) - lr * delta
            return p2.astype(p.dtype), m2.astype(dt), v2.astype(dt)

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamWState(step=step, m=new_m, v=new_v)


class SGDState(NamedTuple):
    step: jax.Array
    momentum: Optional[PyTree]


@dataclass(frozen=True)
class SGD:
    lr: float = 1e-4
    momentum: float = 0.0

    def init(self, params: PyTree) -> SGDState:
        mom = (jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
               if self.momentum else None)
        return SGDState(step=jnp.zeros((), jnp.int32), momentum=mom)

    def update(self, grads: PyTree, state: SGDState, params: PyTree
               ) -> Tuple[PyTree, SGDState]:
        if self.momentum:
            mom = jax.tree.map(
                lambda b, g: self.momentum * b + g.astype(jnp.float32),
                state.momentum, grads)
            step_dir = mom
        else:
            mom = None
            step_dir = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        new_params = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32) - self.lr * d
                          ).astype(p.dtype), params, step_dir)
        return new_params, SGDState(step=state.step + 1, momentum=mom)
