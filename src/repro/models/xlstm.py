"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallel/chunked
training form) and sLSTM (scalar memory, genuinely recurrent), plus the
full xlstm-125m model assembly (init / forward / decode).

mLSTM training uses the stabilized *parallel* form (linear attention with
input/forget-gate decay), query-chunked for long sequences; decode is the
O(1) recurrent update.  sLSTM has no parallel form (recurrent matrix R),
so it scans over time.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamBuilder, shard
from repro.models.layers import (apply_norm, cross_entropy_loss,
                                 embed_tokens, init_norm, init_embedding,
                                 logits_from_hidden)

_NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

class MLSTMState(NamedTuple):
    C: jax.Array   # (B,H,hd,hd) matrix memory
    n: jax.Array   # (B,H,hd)
    m: jax.Array   # (B,H) stabilizer
    conv: jax.Array  # (B,W-1,dc)


def _mlstm_dims(cfg: ModelConfig):
    x = cfg.xlstm
    dc = int(cfg.d_model * x.proj_factor_mlstm)
    H = x.num_heads
    hd = dc // H
    return dc, H, hd


def init_mlstm(pb: ParamBuilder, path: str, cfg: ModelConfig) -> None:
    x = cfg.xlstm
    d = cfg.d_model
    dc, H, hd = _mlstm_dims(cfg)
    init_norm(pb, f"{path}/norm", d, cfg.norm)
    pb.param(f"{path}/w_up", (d, 2 * dc), ("embed", "mlp"))
    pb.param(f"{path}/conv_w", (x.conv_width, dc), (None, "mlp"))
    pb.param(f"{path}/conv_b", (dc,), ("mlp",), init="zeros")
    for nm in ("wq", "wk", "wv"):
        pb.param(f"{path}/{nm}", (dc, H, hd), ("mlp", "heads", "head_dim"))
    pb.param(f"{path}/w_i", (dc, H), ("mlp", "heads"), dtype=jnp.float32)
    pb.param(f"{path}/w_f", (dc, H), ("mlp", "heads"), dtype=jnp.float32)
    pb.param(f"{path}/b_i", (H,), ("heads",), init="zeros", dtype=jnp.float32)
    pb.param(f"{path}/b_f", (H,), ("heads",), init="ones", dtype=jnp.float32)
    pb.param(f"{path}/out_norm", (dc,), ("mlp",), init="ones")
    pb.param(f"{path}/w_down", (dc, d), ("mlp", "embed"))


def _conv_silu(xc, w, b):
    W = w.shape[0]
    pad = jnp.pad(xc, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xc)
    for k in range(W):
        out = out + pad[:, k:k + xc.shape[1], :] * w[k]
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(xc.dtype)


def _head_groupnorm(h: jax.Array, scale: jax.Array, eps=1e-6) -> jax.Array:
    """h (B,T,H,hd) normalized per head then flattened."""
    h32 = h.astype(jnp.float32)
    mu = jnp.mean(h32, axis=-1, keepdims=True)
    var = jnp.var(h32, axis=-1, keepdims=True)
    y = (h32 - mu) * jax.lax.rsqrt(var + eps)
    B, T, H, hd = h.shape
    y = y.reshape(B, T, H * hd) * scale.astype(jnp.float32)
    return y


def mlstm_parallel(q, k, v, logf, logi, q_chunk: int = 2048):
    """Stabilized parallel mLSTM.

    q,k,v (B,T,H,hd); logf/logi (B,T,H).  Returns h (B,T,H,hd)."""
    B, T, H, hd = q.shape
    cumf = jnp.cumsum(logf, axis=1)                          # (B,T,H)
    scale = 1.0 / math.sqrt(hd)

    def block(qc, q_pos, cumf_q):
        # qc (B,c,H,hd); scores vs all keys
        d = (cumf_q[:, :, None, :] - cumf[:, None, :, :]
             + logi[:, None, :, :])                          # (B,c,T,H)
        mask = q_pos[:, None] >= jnp.arange(T)[None, :]      # (c,T)
        d = jnp.where(mask[None, :, :, None], d, _NEG_INF)
        m = jnp.max(d, axis=2, keepdims=True)                # (B,c,1,H)
        dexp = jnp.exp(d - m)
        qk = jnp.einsum("bchd,bthd->bcth", qc.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
        S = qk * dexp
        n = jnp.maximum(jnp.abs(jnp.sum(S, axis=2)),
                        jnp.exp(-m[:, :, 0, :]))             # (B,c,H)
        hout = jnp.einsum("bcth,bthd->bchd", S, v.astype(jnp.float32))
        return hout / n[..., None]

    if T > q_chunk and T % q_chunk == 0:
        nch = T // q_chunk
        qs = q.reshape(B, nch, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)
        cfs = cumf.reshape(B, nch, q_chunk, H).transpose(1, 0, 2, 3)
        pos = jnp.arange(T).reshape(nch, q_chunk)

        def step(_, xs):
            qc, cf, pp = xs
            return None, block(qc, pp, cf)

        _, outs = jax.lax.scan(step, None, (qs, cfs, pos))
        h = outs.transpose(1, 0, 2, 3, 4).reshape(B, T, H, hd)
    else:
        h = block(q, jnp.arange(T), cumf)
    return h.astype(q.dtype)


def apply_mlstm(p: Dict[str, Any], cfg: ModelConfig,
                x: jax.Array) -> jax.Array:
    dc, H, hd = _mlstm_dims(cfg)
    r = apply_norm(p["norm"], x, cfg.norm, cfg.norm_eps)
    up = jnp.einsum("btd,de->bte", r, p["w_up"])
    xi, z = up[..., :dc], up[..., dc:]
    xc = _conv_silu(xi, p["conv_w"], p["conv_b"])
    q = jnp.einsum("bte,ehd->bthd", xc, p["wq"])
    k = jnp.einsum("bte,ehd->bthd", xc, p["wk"])
    v = jnp.einsum("bte,ehd->bthd", xi, p["wv"])
    logi = (jnp.einsum("bte,eh->bth", xc.astype(jnp.float32), p["w_i"])
            + p["b_i"])
    logf = jax.nn.log_sigmoid(
        jnp.einsum("bte,eh->bth", xc.astype(jnp.float32), p["w_f"])
        + p["b_f"])
    h = mlstm_parallel(q, k, v, logf, logi)
    hn = _head_groupnorm(h, p["out_norm"])
    y = (hn * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return x + jnp.einsum("bte,ed->btd", y, p["w_down"])


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    from repro.models.common import to_dtype
    dc, H, hd = _mlstm_dims(cfg)
    W = cfg.xlstm.conv_width
    return MLSTMState(
        C=jnp.zeros((batch, H, hd, hd), jnp.float32),
        n=jnp.zeros((batch, H, hd), jnp.float32),
        m=jnp.full((batch, H), -1e30, jnp.float32),
        conv=jnp.zeros((batch, W - 1, dc), to_dtype(cfg.dtype)),
    )


def mlstm_decode(p, cfg: ModelConfig, x: jax.Array,
                 st: MLSTMState) -> Tuple[jax.Array, MLSTMState]:
    dc, H, hd = _mlstm_dims(cfg)
    r = apply_norm(p["norm"], x, cfg.norm, cfg.norm_eps)
    up = jnp.einsum("btd,de->bte", r, p["w_up"])
    xi, z = up[..., :dc], up[..., dc:]
    buf = jnp.concatenate([st.conv, xi[:, :1].astype(st.conv.dtype)], axis=1)
    co = jnp.einsum("bwc,wc->bc", buf.astype(jnp.float32),
                    p["conv_w"].astype(jnp.float32)) + p["conv_b"]
    xc = jax.nn.silu(co).astype(x.dtype)[:, None, :]
    q = jnp.einsum("bte,ehd->bthd", xc, p["wq"])[:, 0].astype(jnp.float32)
    k = jnp.einsum("bte,ehd->bthd", xc, p["wk"])[:, 0].astype(jnp.float32)
    v = jnp.einsum("bte,ehd->bthd", xi, p["wv"])[:, 0].astype(jnp.float32)
    logi = (jnp.einsum("be,eh->bh", xc[:, 0].astype(jnp.float32), p["w_i"])
            + p["b_i"])
    logf = jax.nn.log_sigmoid(
        jnp.einsum("be,eh->bh", xc[:, 0].astype(jnp.float32), p["w_f"])
        + p["b_f"])
    m_new = jnp.maximum(logf + st.m, logi)
    fg = jnp.exp(logf + st.m - m_new)
    ig = jnp.exp(logi - m_new)
    scale = 1.0 / math.sqrt(hd)
    C = fg[..., None, None] * st.C + ig[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", v, k)
    n = fg[..., None] * st.n + ig[..., None] * k
    num = jnp.einsum("bhde,bhe->bhd", C, q) * scale
    den = jnp.maximum(jnp.abs(jnp.einsum("bhe,bhe->bh", n, q) * scale),
                      jnp.exp(-m_new))
    h = (num / den[..., None])[:, None]                      # (B,1,H,hd)
    hn = _head_groupnorm(h.astype(x.dtype), p["out_norm"])
    y = (hn * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = x + jnp.einsum("bte,ed->btd", y, p["w_down"])
    return out, MLSTMState(C=C, n=n, m=m_new, conv=buf[:, 1:])


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

class SLSTMState(NamedTuple):
    c: jax.Array   # (B,H,hd)
    n: jax.Array
    h: jax.Array
    m: jax.Array   # (B,H,hd)
    conv: jax.Array


def _slstm_dims(cfg: ModelConfig):
    H = cfg.xlstm.num_heads
    hd = cfg.d_model // H
    return H, hd


def init_slstm(pb: ParamBuilder, path: str, cfg: ModelConfig) -> None:
    x = cfg.xlstm
    d = cfg.d_model
    H, hd = _slstm_dims(cfg)
    pf = x.proj_factor_slstm
    dff = int(d * pf)
    init_norm(pb, f"{path}/norm", d, cfg.norm)
    pb.param(f"{path}/conv_w", (x.conv_width, d), (None, "embed"))
    pb.param(f"{path}/conv_b", (d,), ("embed",), init="zeros")
    for g in ("i", "f", "z", "o"):
        pb.param(f"{path}/w_{g}", (d, H, hd), ("embed", "heads", "head_dim"))
        pb.param(f"{path}/r_{g}", (H, hd, hd), ("heads", "head_dim", None))
        pb.param(f"{path}/b_{g}", (H, hd), ("heads", "head_dim"),
                 init="ones" if g == "f" else "zeros", dtype=jnp.float32)
    pb.param(f"{path}/out_norm", (d,), ("embed",), init="ones")
    # post-block gated FFN (proj factor 4/3)
    pb.param(f"{path}/ffn_norm", (d,), ("embed",), init="ones")
    pb.param(f"{path}/w_up", (d, 2 * dff), ("embed", "mlp"))
    pb.param(f"{path}/w_down", (dff, d), ("mlp", "embed"))


def _slstm_cell(p, xt, st: SLSTMState):
    """One sLSTM step.  xt: dict of per-gate inputs (B,H,hd)."""
    def rec(g):
        return jnp.einsum("bhd,hde->bhe", st.h, p[f"r_{g}"])
    zi = xt["i"] + rec("i") + p["b_i"]
    zf = xt["f"] + rec("f") + p["b_f"]
    zz = xt["z"] + rec("z") + p["b_z"]
    zo = xt["o"] + rec("o") + p["b_o"]
    m_new = jnp.maximum(zf + st.m, zi)
    ig = jnp.exp(zi - m_new)
    fg = jnp.exp(zf + st.m - m_new)
    c = fg * st.c + ig * jnp.tanh(zz)
    n = fg * st.n + ig
    h = jax.nn.sigmoid(zo) * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c=c, n=n, h=h, m=m_new, conv=st.conv)


def apply_slstm(p: Dict[str, Any], cfg: ModelConfig,
                x: jax.Array) -> jax.Array:
    H, hd = _slstm_dims(cfg)
    B, T, d = x.shape
    r = apply_norm(p["norm"], x, cfg.norm, cfg.norm_eps)
    xc = _conv_silu(r, p["conv_w"], p["conv_b"])
    gates = {}
    for g, src in (("i", xc), ("f", xc), ("z", r), ("o", r)):
        gates[g] = jnp.einsum("btd,dhe->bthe", src,
                              p[f"w_{g}"]).astype(jnp.float32)

    st0 = SLSTMState(
        c=jnp.zeros((B, H, hd), jnp.float32),
        n=jnp.zeros((B, H, hd), jnp.float32),
        h=jnp.zeros((B, H, hd), jnp.float32),
        m=jnp.full((B, H, hd), -1e30, jnp.float32),
        conv=jnp.zeros((B, 0, 0), jnp.float32),
    )

    def step(st, gts):
        st2 = _slstm_cell(p, gts, st)
        return st2, st2.h

    xs = {g: gates[g].transpose(1, 0, 2, 3) for g in gates}
    _, hs = jax.lax.scan(step, st0, xs)
    h = hs.transpose(1, 0, 2, 3)                              # (B,T,H,hd)
    hn = _head_groupnorm(h.astype(x.dtype), p["out_norm"]).astype(x.dtype)
    y = x + hn
    # gated FFN
    rn = apply_norm({"scale": p["ffn_norm"]}, y, "rmsnorm", cfg.norm_eps)
    up = jnp.einsum("btd,de->bte", rn, p["w_up"])
    dff = up.shape[-1] // 2
    gelu = jax.nn.gelu(up[..., :dff].astype(jnp.float32)).astype(x.dtype)
    return y + jnp.einsum("bte,ed->btd", gelu * up[..., dff:], p["w_down"])


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    from repro.models.common import to_dtype
    H, hd = _slstm_dims(cfg)
    W = cfg.xlstm.conv_width
    return SLSTMState(
        c=jnp.zeros((batch, H, hd), jnp.float32),
        n=jnp.zeros((batch, H, hd), jnp.float32),
        h=jnp.zeros((batch, H, hd), jnp.float32),
        m=jnp.full((batch, H, hd), -1e30, jnp.float32),
        conv=jnp.zeros((batch, W - 1, cfg.d_model), to_dtype(cfg.dtype)),
    )


def slstm_decode(p, cfg: ModelConfig, x: jax.Array,
                 st: SLSTMState) -> Tuple[jax.Array, SLSTMState]:
    H, hd = _slstm_dims(cfg)
    B = x.shape[0]
    r = apply_norm(p["norm"], x, cfg.norm, cfg.norm_eps)
    buf = jnp.concatenate([st.conv, r[:, :1].astype(st.conv.dtype)], axis=1)
    co = jnp.einsum("bwc,wc->bc", buf.astype(jnp.float32),
                    p["conv_w"].astype(jnp.float32)) + p["conv_b"]
    xc = jax.nn.silu(co).astype(x.dtype)[:, None]
    gates = {}
    for g, src in (("i", xc), ("f", xc), ("z", r), ("o", r)):
        gates[g] = jnp.einsum("btd,dhe->bthe", src,
                              p[f"w_{g}"]).astype(jnp.float32)[:, 0]
    st_in = SLSTMState(c=st.c, n=st.n, h=st.h, m=st.m, conv=st.conv)
    st2 = _slstm_cell(p, gates, st_in)
    hn = _head_groupnorm(st2.h[:, None].astype(x.dtype), p["out_norm"]
                         ).astype(x.dtype)
    y = x + hn
    rn = apply_norm({"scale": p["ffn_norm"]}, y, "rmsnorm", cfg.norm_eps)
    up = jnp.einsum("btd,de->bte", rn, p["w_up"])
    dff = up.shape[-1] // 2
    gelu = jax.nn.gelu(up[..., :dff].astype(jnp.float32)).astype(x.dtype)
    out = y + jnp.einsum("bte,ed->btd", gelu * up[..., dff:], p["w_down"])
    return out, SLSTMState(c=st2.c, n=st2.n, h=st2.h, m=st2.m,
                           conv=buf[:, 1:])


# ---------------------------------------------------------------------------
# xlstm-125m model assembly
# ---------------------------------------------------------------------------

def init_params(rng, cfg: ModelConfig):
    from repro.models.common import to_dtype
    pb = ParamBuilder(rng, dtype=to_dtype(cfg.param_dtype))
    init_embedding(pb, cfg)
    for i in range(cfg.num_layers):
        if i in cfg.xlstm.slstm_layers:
            init_slstm(pb, f"blocks/{i}", cfg)
        else:
            init_mlstm(pb, f"blocks/{i}", cfg)
    init_norm(pb, "final_norm", cfg.d_model, cfg.norm)
    return pb.build()


def forward(params, cfg: ModelConfig, tokens: jax.Array,
            extra_embeds=None, remat: str = "layer"
            ) -> Tuple[jax.Array, jax.Array]:
    x = embed_tokens(params, cfg, tokens)
    mlstm_fn = apply_mlstm if remat == "none" else jax.checkpoint(
        apply_mlstm, static_argnums=(1,))
    slstm_fn = apply_slstm if remat == "none" else jax.checkpoint(
        apply_slstm, static_argnums=(1,))
    for i in range(cfg.num_layers):
        p = params["blocks"][str(i)]
        if i in cfg.xlstm.slstm_layers:
            x = slstm_fn(p, cfg, x)
        else:
            x = mlstm_fn(p, cfg, x)
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    return logits_from_hidden(params, cfg, x), jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, capacity: int,
               dtype=None):  # states are fp32; conv follows cfg.dtype
    cache = {}
    for i in range(cfg.num_layers):
        if i in cfg.xlstm.slstm_layers:
            cache[str(i)] = init_slstm_state(cfg, batch)
        else:
            cache[str(i)] = init_mlstm_state(cfg, batch)
    return cache


def decode_step(params, cfg: ModelConfig, tokens: jax.Array, pos: jax.Array,
                cache, extra_embeds=None):
    x = embed_tokens(params, cfg, tokens)
    new_cache = {}
    for i in range(cfg.num_layers):
        p = params["blocks"][str(i)]
        if i in cfg.xlstm.slstm_layers:
            x, new_cache[str(i)] = slstm_decode(p, cfg, x, cache[str(i)])
        else:
            x, new_cache[str(i)] = mlstm_decode(p, cfg, x, cache[str(i)])
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    return logits_from_hidden(params, cfg, x), new_cache
