"""Render the §Dry-run / §Roofline tables in EXPERIMENTS.md from the
results/dryrun JSON records."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ORDER_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirpath="results/dryrun") -> List[Dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def dryrun_table(records, mesh="16x16") -> str:
    lines = ["| arch | shape | compile | args/dev | act-peak/dev | fits 16G |"
             " collective ops (AR/AG/RS/A2A/CP) |",
             "|---|---|---|---|---|---|---|"]
    recs = [r for r in records if r.get("mesh") == mesh]
    recs.sort(key=lambda r: (r["arch"], ORDER_SHAPES.index(r["shape"])))
    for r in recs:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | FAIL | - | - | - |"
                         f" {r.get('error', '')[:40]} |")
            continue
        m = r["memory"]
        c = r["roofline"]["collective_counts"]
        ops = "/".join(str(c.get(k, 0)) for k in
                       ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_s']:.0f}s "
            f"| {m['argument_bytes'] / 1e9:.2f} GB "
            f"| {m.get('activation_peak_bytes_analytic', 0) / 1e9:.2f} GB "
            f"| {'yes' if m.get('fits_hbm') else 'NO'} | {ops} |")
    return "\n".join(lines)


def roofline_table(records, mesh="16x16") -> str:
    lines = ["| arch | shape | compute | memory | collective | dominant |"
             " MFU bound | useful/HLO* |",
             "|---|---|---|---|---|---|---|---|"]
    recs = [r for r in records if r.get("mesh") == mesh and r.get("ok")]
    recs.sort(key=lambda r: (r["arch"], ORDER_SHAPES.index(r["shape"])))
    for r in recs:
        a = r["analytic"]
        hlo = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(a['compute_s'])} "
            f"| {fmt_s(a['memory_s'])} | {fmt_s(a['collective_s'])} "
            f"| **{a['dominant']}** | {a.get('mfu_upper_bound', 0):.2f} "
            f"| {hlo.get('useful_flops_ratio', 0):.2f} |")
    return "\n".join(lines)


def summarize(records) -> Dict:
    ok = [r for r in records if r.get("ok")]
    doms = {}
    for r in ok:
        doms.setdefault(r["analytic"]["dominant"], []).append(
            (r["arch"], r["shape"], r["mesh"]))
    return {"total": len(records), "ok": len(ok), "dominant": doms}


def main():
    recs = load()
    s = summarize(recs)
    print(f"{s['ok']}/{s['total']} combos OK")
    for k, v in s["dominant"].items():
        print(f"  dominant={k}: {len(v)}")
    print()
    print(dryrun_table(recs))
    print()
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
