"""Logical-axis -> mesh-axis sharding rules (MaxText-style), with
divisibility fallback so every assigned architecture lowers on the fixed
production mesh (gemma3's 4 heads / kv=1, qwen's 60 experts, whisper's
odd vocab are all handled by padding or fallback-to-replicated).

Weights: ``embed`` is FSDP-sharded over "data"; ``mlp``/``heads``/
``vocab`` are tensor-parallel over "model".  Activations: ``batch`` over
("pod","data") [("cluster","data") on HFL meshes], hidden dims over
"model".
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import attention as attn_mod
from repro.models.common import named_sharding_for
from repro.models.ssm import SSMState
from repro.models.xlstm import MLSTMState, SLSTMState

PyTree = Any

# weight + activation rules (logical axis -> preferred mesh axes)
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    # weights
    "embed": ("data",),             # FSDP
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "vocab": ("model",),
    "expert": (),                   # experts replicated; d_ff sharded
    "kv_lora": ("model",),
    "layers": (),
    # activations
    "batch": ("pod", "cluster", "data"),
    "seq": (),
    "embed_act": ("model",),
    "mlp_act": ("model",),
    "heads_act": ("model",),
    "kv_heads_act": ("model",),
    "vocab_act": ("model",),
    # caches
    "kv_seq": ("data", "model"),
    "cluster": ("pod", "cluster"),
}

EXPERT_PARALLEL_RULES = dict(DEFAULT_RULES, expert=("model",), mlp=(),
                             mlp_act=())


def rules_for(cfg, mesh, overrides=()) -> Dict[str, Tuple[str, ...]]:
    rules = dict(DEFAULT_RULES)
    for k, v in overrides or ():
        rules[k] = tuple(v)
    return rules


def params_shardings(axes_tree: PyTree, shapes_tree: PyTree, mesh,
                     rules) -> PyTree:
    """NamedSharding tree for parameters given their logical-axes tree."""
    def one(axes, shape_struct):
        return named_sharding_for(mesh, rules, axes, shape_struct.shape)

    is_axes = lambda x: isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)
    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=is_axes)


def batch_shardings(batch_specs: Dict[str, jax.ShapeDtypeStruct], mesh,
                    rules, cluster_dim: bool = False) -> Dict[str, Any]:
    """tokens/labels (B,S): batch over data axes.  patches/frames
    (B,P,d): hidden over model.  HFL mode adds a leading cluster dim."""
    out = {}
    lead = ("cluster",) if cluster_dim else ()
    for k, v in batch_specs.items():
        if v.ndim - len(lead) == 2 and k in ("tokens", "labels"):
            logical = lead + ("batch", "seq")
        elif k in ("patches", "frames"):
            logical = lead + ("batch", "seq", "embed_act")
        elif k == "windows":
            logical = lead + ("batch", "seq", None)
        elif k == "targets":
            logical = lead + ("batch", None)
        else:
            logical = (None,) * v.ndim
        out[k] = named_sharding_for(mesh, rules, logical, v.shape)
    return out


# ---------------------------------------------------------------------------
# cache shardings (decode dry-run inputs)
# ---------------------------------------------------------------------------

def _cache_leaf_sharding(path_types, leaf, mesh, rules):
    shape = leaf.shape
    name = path_types
    if name in ("k", "v"):           # KVCache (B,C,H,D)
        logical = ("batch", "kv_seq", "kv_heads_act", None)
    elif name == "c_kv":             # MLA latents (B,C,R)
        logical = ("batch", "kv_seq", "mlp_act")
    elif name == "k_rope":
        logical = ("batch", "kv_seq", None)
    elif name == "pos":
        logical = ("batch", "kv_seq")
    elif name == "conv":             # SSM conv buffer (B,W-1,ch)
        logical = ("batch", None, "mlp_act")
    elif name == "s":                # SSD state (B,H,N,P)
        logical = ("batch", "heads_act", None, None)
    elif name == "C":                # mLSTM matrix memory (B,H,hd,hd)
        logical = ("batch", "heads_act", None, None)
    elif name in ("n", "h", "c", "m"):
        logical = ("batch", "heads_act") + (None,) * (leaf.ndim - 2)
    elif name in ("cross_k", "cross_v"):   # (L,B,F,H,D)
        logical = (None, "batch", None, "kv_heads_act", None)
    elif name == "index":
        logical = ()
    else:
        logical = (None,) * leaf.ndim
    # stacked caches carry a leading layer dim: shift logical axes
    if leaf.ndim > len(logical):
        logical = (None,) * (leaf.ndim - len(logical)) + logical
    logical = logical[:leaf.ndim]
    return named_sharding_for(mesh, rules, logical, shape)


def cache_shardings(cache_tree: PyTree, mesh, rules) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    out = []
    for path, leaf in flat:
        field = None
        for k in reversed(path):
            if hasattr(k, "name"):
                field = k.name
                break
            if hasattr(k, "key"):
                field = str(k.key)
                break
        out.append(_cache_leaf_sharding(field, leaf, mesh, rules))
    return jax.tree_util.tree_unflatten(treedef, out)


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def scalar_shardings(tree: PyTree, mesh) -> PyTree:
    return jax.tree.map(lambda _: replicated(mesh), tree)
