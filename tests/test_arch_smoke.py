"""Per-architecture smoke tests (deliverable f): every assigned arch in a
REDUCED variant of the same family runs one forward + one train step on
CPU with correct output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, applicable_shapes, get_config
from repro.configs.registry import ASSIGNED
from repro.models import make_model
from repro.training.optimizer import AdamW
from repro.training.train_step import make_train_step

SEQ = 32
BATCH = 2


def _batch_for(cfg, rng, B=BATCH, S=SEQ):
    m = cfg.model
    if m.family == "rnn":
        return {"windows": jnp.asarray(rng.normal(size=(B, 12, 1)),
                                       jnp.float32),
                "targets": jnp.asarray(rng.normal(size=(B, 1)), jnp.float32)}
    b = {"tokens": jnp.asarray(rng.integers(0, m.vocab_size, (B, S)),
                               jnp.int32),
         "labels": jnp.asarray(rng.integers(0, m.vocab_size, (B, S)),
                               jnp.int32)}
    if m.family == "vlm":
        b["patches"] = jnp.asarray(
            rng.normal(size=(B, m.frontend.num_positions, m.d_model)) * .02,
            jnp.bfloat16)
    if m.family == "audio":
        b["frames"] = jnp.asarray(
            rng.normal(size=(B, m.frontend.num_positions, m.d_model)) * .02,
            jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", list(ASSIGNED) + ["gru-traffic"])
def test_reduced_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.model.d_model <= 512
    if cfg.model.family != "rnn":
        assert cfg.model.num_layers == 2
    if cfg.model.moe:
        assert cfg.model.moe.num_experts <= 4
    api = make_model(cfg)
    rng = np.random.default_rng(0)
    params, axes = api.init_params(jax.random.key(0))
    batch = _batch_for(cfg, rng)
    # forward shapes
    if cfg.model.family != "rnn":
        logits, aux = api.forward(params, batch)
        S_total = batch["tokens"].shape[1]
        if cfg.model.family == "vlm":
            S_total += batch["patches"].shape[1]
        assert logits.shape == (BATCH, S_total, cfg.model.padded_vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
    # one train step
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(api, cfg, opt))
    opt_state = opt.init(params)
    new_params, _, loss = step(params, opt_state, batch)
    assert np.isfinite(float(loss))
    # params actually changed
    changed = jax.tree.map(
        lambda a, b: bool(np.any(np.asarray(a, np.float32)
                                 != np.asarray(b, np.float32))),
        params, new_params)
    assert any(jax.tree.leaves(changed))


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_reduced_decode_step(arch):
    cfg = get_config(arch).reduced()
    api = make_model(cfg)
    rng = np.random.default_rng(1)
    params, _ = api.init_params(jax.random.key(1))
    cache = api.init_cache(BATCH, 64)
    tok = jnp.asarray(rng.integers(0, cfg.model.vocab_size, (BATCH, 1)),
                      jnp.int32)
    logits, cache2 = api.decode_step(params, tok, jnp.int32(0), cache)
    assert logits.shape == (BATCH, 1, cfg.model.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # second step with updated cache
    logits2, _ = api.decode_step(params, tok, jnp.int32(1), cache2)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_long_context_applicability_table():
    """DESIGN.md §4 skip table is encoded in the configs."""
    expect_long = {"zamba2-1.2b", "xlstm-125m", "h2o-danube-1.8b",
                   "gemma3-1b"}
    for name, cfg in all_configs().items():
        shapes = {s.name for s in applicable_shapes(cfg)}
        assert ("long_500k" in shapes) == (name in expect_long), name
        assert {"train_4k", "prefill_32k", "decode_32k"} <= shapes
