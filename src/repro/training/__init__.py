from repro.training.optimizer import SGD, AdamW, AdamWState, SGDState
from repro.training.train_step import (hfl_global_round, make_eval_step,
                                       make_hfl_train_step, make_train_step)

__all__ = ["SGD", "AdamW", "AdamWState", "SGDState", "hfl_global_round",
           "make_eval_step", "make_hfl_train_step", "make_train_step"]
